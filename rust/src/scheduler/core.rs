//! [`SchedulerCore`] — the paper's §3.4 decision loop as one reusable state
//! machine, shared verbatim by the discrete-event simulator and the real
//! engine ("only the clock is virtual").
//!
//! The core owns every scheduling decision of the four coordinator points
//! (gating, migration/Algorithm 1, mix-decode/Algorithm 2, preemption +
//! bottleneck-aware eviction) plus routing and KV accounting, exposed
//! through three step-boundary entry points:
//!
//! - [`SchedulerCore::on_arrival`] — a request reached the cluster;
//! - [`SchedulerCore::on_step_end`] — an iteration finished on an instance;
//! - [`SchedulerCore::on_transfer_progress`] — a KV transfer chunk landed.
//!
//! Each returns the typed [`Action`]s the executor must carry out. The core
//! never sleeps, measures, or schedules: time enters exclusively through the
//! `now` argument of the entry points, which is a virtual clock under
//! [`super::VirtualExecutor`] and a wall clock under the engine's executor.
//!
//! All inter-instance KV movement flows through the embedded
//! [`TransportEngine`] (link contention, chunked layer-wise transfers,
//! recoverable fast preemption — DESIGN.md §3.5); the core turns its chunk
//! orders into [`Action::TransferChunk`] work orders so the transfer
//! timeline is part of the substrate-independent action stream.

use crate::config::{ChunkMode, ServingConfig};
use crate::coordinator::{
    migration_decision, pick_migration_candidates, preemption_delay,
    select_decode_batch, select_decode_batch_capped, select_evictions,
    shed_online_overload, Ablation, Candidate, LengthPref, OverloadMode,
    Policy,
};
use crate::instance::{
    Instance, PoolRole, PrefillSegment, Step, StepKind,
};
use crate::metrics::{
    ChunkReport, LinkReport, PoolReport, PrefixReport, TransportReport,
};
use crate::obs::{self, Subsystem};
use crate::perfmodel::{BatchStats, PerfModel};
use crate::pool::{PoolManager, Transition, TransitionPhase, WARMUP_S};
use crate::prefix::PrefixMatch;
use crate::request::{arena::Recycler, Phase, Request, RequestId};
use crate::transport::{
    ChunkOrder, JobId, Progress, TransferJob, TransferKind, TransportEngine,
};
use crate::util::rng::Pcg;

use super::action::{Action, InstanceRef, RolePhase};
use super::cluster::{ClusterState, KvHome};

/// KV tokens kept free on a relaxed instance for a typical online prefill,
/// so offline admission paths (gated prefill, staged-KV restore, strict
/// rescue) don't crowd out preempting arrivals. One constant, three users —
/// the headrooms are deliberately coupled.
const ONLINE_PREFILL_RESERVE_TOKENS: usize = 4096;

/// Minimum per-iteration chunk quantum of the chunked-prefill model
/// (DESIGN.md §3.8): even when the decode batch alone exhausts the
/// latency budget, prefill cursors keep advancing by at least this many
/// tokens per iteration — the progress guarantee that makes long prompts
/// servable under sustained decode pressure.
const MIN_CHUNK_TOKENS: usize = 512;

/// Configuration of the decision core (substrate-independent: no drain
/// horizon, no wall-clock compression — those belong to executors).
#[derive(Debug, Clone)]
pub struct CoreConfig {
    pub serving: ServingConfig,
    pub policy: Policy,
    pub ablation: Ablation,
    /// §3.4.4 behaviour when the online-only batch exceeds the SLO bound.
    pub overload_mode: OverloadMode,
    /// KV page size in tokens.
    pub block_tokens: usize,
    pub seed: u64,
}

impl CoreConfig {
    pub fn new(serving: ServingConfig, policy: Policy) -> Self {
        CoreConfig {
            serving,
            policy,
            ablation: Ablation::full(),
            overload_mode: OverloadMode::BestEffort,
            block_tokens: 16,
            seed: 0,
        }
    }
}

/// Outcome of a chunked admission attempt (DESIGN.md §3.8).
enum AdmitChunk {
    /// Admitted; the first chunk segment joins this iteration, with the
    /// admission's cache-resolved token count.
    Scheduled(PrefillSegment, usize),
    /// Head online request cannot fit even after eviction: dropped.
    Rejected,
    /// No budget/space/gating headroom right now; try next iteration.
    NoSpace,
}

/// The unified §3.4 scheduling state machine.
#[derive(Debug)]
pub struct SchedulerCore {
    pub cfg: CoreConfig,
    pub pm: PerfModel,
    pub cluster: ClusterState,
    /// The KV transport subsystem: every inter-instance (and host-staging)
    /// KV movement is a chunked job on its modeled links.
    pub transport: TransportEngine,
    /// The elastic pool manager (DESIGN.md §3.6): load estimation,
    /// Roofline-guided repartition planning, and drain/flip/warm
    /// role-transition bookkeeping above the per-step decisions.
    pub pool: PoolManager,
    /// Mix-decode probe randomness (Algorithm 2's starvation avoidance).
    rng: Pcg,
    /// Clock of the most recent entry-point invocation.
    now: f64,
    /// Action buffer of the entry point currently executing.
    actions: Vec<Action>,
    // ---- hot-loop scratch buffers (reused across steps; contents are
    // garbage between uses and every user clears before filling) ----
    scratch_ids: Vec<RequestId>,
    scratch_online: Vec<Candidate>,
    scratch_offline: Vec<Candidate>,
    // ---- recycled-capacity pools (DESIGN.md §3.13): spent buffers
    // handed back by the executor and by ended steps, reused so the
    // per-event steady state allocates nothing. Pooled vecs are always
    // empty; capacity is what gets recycled. ----
    spare_actions: Recycler<Vec<Action>>,
    id_pool: Recycler<Vec<RequestId>>,
    seg_pool: Recycler<Vec<PrefillSegment>>,
}

/// Bound on each recycled-buffer pool; beyond it spares drop to the
/// allocator (the steady state never gets near this).
const POOL_CAP: usize = 64;

impl SchedulerCore {
    /// Build a core whose perf model derives from `cfg.serving` (the
    /// simulator path; the engine calibrates its own model instead).
    pub fn new(requests: Vec<Request>, cfg: CoreConfig) -> Self {
        let pm = PerfModel::new(
            cfg.serving.model.clone(),
            cfg.serving.hardware.clone(),
        );
        Self::with_perf_model(requests, cfg, pm)
    }

    /// Build a core around an explicit (e.g. runtime-calibrated) perf model.
    pub fn with_perf_model(
        requests: Vec<Request>,
        cfg: CoreConfig,
        pm: PerfModel,
    ) -> Self {
        let cap = pm.max_kv_tokens().max(cfg.block_tokens);
        let cluster = ClusterState::new(
            requests,
            cfg.serving.cluster.relaxed_instances,
            cfg.serving.cluster.strict_instances,
            cap,
            cfg.block_tokens,
        );
        let rng = Pcg::new(cfg.seed, 9090);
        let transport = TransportEngine::new(
            &cfg.serving.transport,
            cfg.serving.model.kv_bytes_per_token(),
            cfg.serving.model.layers,
        );
        let pool = PoolManager::new(cfg.serving.pool);
        // The planner's sizing path prices candidate batches as composed
        // iterations (`max_slo_batch_chunked`, DESIGN.md §3.8). In this
        // architecture the *strict* pool runs pure-decode iterations —
        // prefill chunks compose only on relaxed instances — so its chunk
        // reserve stays 0: charging strict capacity for prefill it never
        // schedules would systematically oversize the strict pool. A
        // substrate that fuses prefill into SLO-bounded iterations sets
        // `PoolManager::set_chunk_reserve` to its per-iteration quantum.
        SchedulerCore {
            cfg,
            pm,
            cluster,
            transport,
            pool,
            rng,
            now: 0.0,
            actions: Vec::new(),
            scratch_ids: Vec::new(),
            scratch_online: Vec::new(),
            scratch_offline: Vec::new(),
            spare_actions: Recycler::new(POOL_CAP),
            id_pool: Recycler::new(POOL_CAP),
            seg_pool: Recycler::new(POOL_CAP),
        }
    }

    /// Clock of the most recent entry-point invocation.
    pub fn now(&self) -> f64 {
        self.now
    }

    // ------------------------------------------------- capacity recycling

    /// Hand the entry point's action batch to the caller, swapping in a
    /// recycled buffer so the steady-state loop allocates no action vecs.
    fn drain_actions(&mut self) -> Vec<Action> {
        let fresh = self.spare_actions.take().unwrap_or_default();
        std::mem::replace(&mut self.actions, fresh)
    }

    /// Return a spent action batch to the pool. When the executor did not
    /// keep the actions (the no-log hot path), the id/segment vecs inside
    /// `StartStep`s are harvested too.
    pub fn recycle_actions(&mut self, mut actions: Vec<Action>) {
        for a in actions.drain(..) {
            if let Action::StartStep {
                participants,
                prefill,
                ..
            } = a
            {
                self.recycle_ids(participants);
                self.recycle_segs(prefill);
            }
        }
        self.spare_actions.put(actions);
    }

    /// Take a cleared request-id buffer from the pool (or a fresh one).
    fn pooled_ids(&mut self) -> Vec<RequestId> {
        self.id_pool.take().unwrap_or_default()
    }

    /// Take a cleared prefill-segment buffer from the pool.
    fn pooled_segs(&mut self) -> Vec<PrefillSegment> {
        self.seg_pool.take().unwrap_or_default()
    }

    fn recycle_ids(&mut self, mut v: Vec<RequestId>) {
        if v.capacity() > 0 {
            v.clear();
            self.id_pool.put(v);
        }
    }

    fn recycle_segs(&mut self, mut v: Vec<PrefillSegment>) {
        if v.capacity() > 0 {
            v.clear();
            self.seg_pool.put(v);
        }
    }

    /// Recycle an ended (or crash-discarded) step's body buffers.
    fn recycle_step(&mut self, step: Step) {
        let Step {
            participants,
            prefill,
            ..
        } = step;
        self.recycle_ids(participants);
        self.recycle_segs(prefill);
    }

    // ------------------------------------------------------- entry points

    /// A request arrived at time `now`.
    pub fn on_arrival(&mut self, now: f64, rid: RequestId) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        let (prompt, output) = {
            let r = &self.cluster.requests[rid as usize];
            (r.prompt_len, r.output_len)
        };
        // Estimate by *scheduled* class: `base P/D` pushes offline
        // requests through the online/strict path, so for pool sizing they
        // are online load — classifying by raw `Class` would starve the
        // strict pool under that policy.
        let class = if self.scheduled_online(rid) {
            crate::request::Class::Online
        } else {
            crate::request::Class::Offline
        };
        self.pool.observe_arrival(now, class, prompt, output);
        self.arrival(rid);
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    /// The step with sequence id `seq` on `inst` finished at `now`. Stale
    /// sequence ids (superseded by a preemption reschedule) are ignored.
    pub fn on_step_end(
        &mut self,
        now: f64,
        inst: InstanceRef,
        seq: u64,
    ) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        match inst {
            InstanceRef::Relaxed(i) => self.relaxed_step_end(i, seq),
            InstanceRef::Strict(i) => self.strict_step_end(i, seq),
        }
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    /// A chunk of transfer `job` completed on its link at `now`. Stale
    /// (cancel-reaped or superseded) completions are ignored. When the
    /// job's final chunk lands, the KV residency hand-off happens here.
    pub fn on_transfer_progress(
        &mut self,
        now: f64,
        job: JobId,
        seq: u64,
    ) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        match self.transport.on_chunk_done(now, job, seq) {
            Progress::Stale => {}
            Progress::Advanced { orders } => self.emit_chunk_orders(orders),
            Progress::JobDone { job, orders } => {
                self.emit_chunk_orders(orders);
                self.actions.push(Action::TransferDone {
                    job: job.id,
                    req: job.req,
                    kind: job.kind,
                });
                self.land_transfer(job);
            }
        }
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    /// Advance crash notice for `inst` at `now` (spot-instance style,
    /// DESIGN.md §3.9): the instance stops taking new work and its
    /// resident offline KV starts evacuating through the same
    /// recoverable-eviction transport paths a drain uses, so the KV
    /// survives the coming crash in host staging or on a live relaxed
    /// instance instead of being recomputed from scratch. Queued work
    /// that holds no KV re-routes immediately. Online decode residents
    /// keep running in place — moving them would violate the very SLO
    /// the evacuation protects; whatever is still resident when the
    /// crash fires is lost and recomputed then.
    pub fn on_crash_notice(
        &mut self,
        now: f64,
        inst: InstanceRef,
    ) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        match inst {
            InstanceRef::Relaxed(i) => {
                self.abort_transition_for(PoolRole::Relaxed, i);
                self.cluster.relaxed[i].evacuating = true;
                self.cluster.router.set_down_relaxed(i, true);
                self.reroute_online_queue(i);
            }
            InstanceRef::Strict(i) => {
                self.abort_transition_for(PoolRole::Strict, i);
                self.cluster.strict[i].evacuating = true;
                self.cluster.router.set_down_strict(i, true);
                self.redispatch_waiting(i);
            }
        }
        // The epilogue's evacuation tick performs the first sweep; later
        // entry points keep sweeping until the crash fires.
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    /// Instance `inst` crashed at `now` (DESIGN.md §3.9): its KV and
    /// running step are lost. Online residents re-route to live instances
    /// for full-context recompute, offline residents return to the
    /// backlog (whatever an advance-notice evacuation already streamed
    /// off is spared), inbound transfers are cancelled, and the elastic
    /// pool manager re-plans around the hole. Crashing the last live
    /// instance of a pool is refused upstream (fleet fault injection
    /// skips it), so routing always has a live target.
    pub fn on_instance_down(
        &mut self,
        now: f64,
        inst: InstanceRef,
    ) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        self.actions.push(Action::InstanceDown { inst });
        match inst {
            InstanceRef::Relaxed(i) => self.crash_relaxed(i),
            InstanceRef::Strict(i) => self.crash_strict(i),
        }
        self.cluster.crashes += 1;
        // Backlogged recompute work may start right away elsewhere.
        self.kick_idle_relaxed();
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    /// Crashed instance `inst` recovered at `now` and rejoins its pool
    /// empty. A relaxed recovery immediately offers its capacity to
    /// staged KV (restores) and the backlog; a strict recovery fills up
    /// through the ordinary dispatch/migration paths.
    pub fn on_instance_up(
        &mut self,
        now: f64,
        inst: InstanceRef,
    ) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        self.actions.push(Action::InstanceUp { inst });
        match inst {
            InstanceRef::Relaxed(i) => {
                assert!(
                    self.cluster.relaxed[i].down,
                    "recovery of a live instance"
                );
                self.cluster.relaxed[i].down = false;
                self.cluster.router.set_down_relaxed(i, false);
                self.try_restores();
                self.kick_idle_relaxed();
            }
            InstanceRef::Strict(i) => {
                assert!(
                    self.cluster.strict[i].down,
                    "recovery of a live instance"
                );
                self.cluster.strict[i].down = false;
                self.cluster.router.set_down_strict(i, false);
            }
        }
        self.cluster.recoveries += 1;
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    /// The crash a notice announced never fired (the fleet refused to kill
    /// the last live instance of a pool): stand the instance back up. Any
    /// KV already evacuated stays evacuated — it restores through the
    /// ordinary staged-KV path.
    pub fn on_crash_averted(
        &mut self,
        now: f64,
        inst: InstanceRef,
    ) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        match inst {
            InstanceRef::Relaxed(i) => {
                assert!(
                    self.cluster.relaxed[i].evacuating,
                    "averting a crash that was never noticed"
                );
                self.cluster.relaxed[i].evacuating = false;
                self.cluster.router.set_down_relaxed(i, false);
                self.kick_idle_relaxed();
            }
            InstanceRef::Strict(i) => {
                assert!(
                    self.cluster.strict[i].evacuating,
                    "averting a crash that was never noticed"
                );
                self.cluster.strict[i].evacuating = false;
                self.cluster.router.set_down_strict(i, false);
            }
        }
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    /// Cross-replica work stealing, victim side (DESIGN.md §3.9): surrender
    /// the *tail* backlog entry — the FIFO front keeps its place — along
    /// with its request state (an offline backlog entry holds no KV, so the
    /// state struct is the whole request). Returns `None` when there is
    /// nothing to steal.
    pub fn steal_out(&mut self, _now: f64) -> Option<(RequestId, Request)> {
        let rid = self.cluster.offline_backlog.pop_back()?;
        debug_assert_eq!(
            self.cluster.kv_home[rid as usize],
            KvHome::None,
            "backlog entries hold no KV"
        );
        Some((rid, self.cluster.requests[rid as usize].clone()))
    }

    /// Cross-replica work stealing, thief side: adopt `state` (the victim's
    /// request copy, carrying any generated-token progress) into this
    /// replica's slot and queue it for offline admission.
    pub fn steal_in(
        &mut self,
        now: f64,
        rid: RequestId,
        state: Request,
    ) -> Vec<Action> {
        self.now = now;
        self.cluster.accrue_cache_seconds(now);
        debug_assert_eq!(
            self.cluster.kv_home[rid as usize],
            KvHome::None,
            "stolen request must not be resident here"
        );
        self.cluster.requests[rid as usize] = state;
        self.cluster.evict_started[rid as usize] = f64::NAN;
        self.cluster.offline_backlog.push_back(rid);
        self.kick_idle_relaxed();
        self.pool_tick();
        self.flush_cache_events();
        self.drain_actions()
    }

    // ------------------------------------- crash mechanics (DESIGN.md §3.9)

    /// Drop the in-flight role transition if it involves instance
    /// (`role`, `idx`) — a crashed instance can neither finish draining
    /// (the flip would move a corpse) nor finish warming (its warm step
    /// died with it).
    fn abort_transition_for(&mut self, role: PoolRole, idx: usize) {
        let Some(t) = self.pool.transition else {
            return;
        };
        let (t_role, t_idx) = match t.phase {
            TransitionPhase::Drain => (t.from, t.inst),
            // After the flip the instance lives in the destination pool.
            TransitionPhase::Warm => (t.from.other(), t.inst),
        };
        if (t_role, t_idx) != (role, idx) {
            return;
        }
        self.pool.abort_transition();
        if t.phase == TransitionPhase::Drain {
            match t.from {
                PoolRole::Relaxed => {
                    self.cluster.relaxed[idx].draining = false;
                    self.cluster.router.set_drain_relaxed(None);
                }
                PoolRole::Strict => {
                    self.cluster.strict[idx].draining = false;
                    self.cluster.router.set_drain_strict(None);
                }
            }
        }
    }

    /// Re-route queued online prefills (no KV yet, no loss) off a crashed
    /// or evacuating relaxed instance to the live pool.
    fn reroute_online_queue(&mut self, inst: usize) {
        let moved: Vec<RequestId> =
            self.cluster.relaxed[inst].online_queue.drain(..).collect();
        for rid in moved {
            let tokens = self.cluster.requests[rid as usize].recompute_len();
            let target = self.cluster.router.route_prefill(tokens);
            self.cluster.relaxed[target].online_queue.push_back(rid);
        }
        self.kick_idle_relaxed();
    }

    /// Evacuate crash-noticed instances: one sweep per entry point — the
    /// same cadence the drain ticks use — streaming resident offline KV
    /// off through the recoverable-eviction paths. Step participants are
    /// skipped until their iteration boundary, exactly like a drain.
    fn evacuation_tick(&mut self) {
        for i in 0..self.cluster.relaxed.len() {
            if self.cluster.relaxed[i].evacuating {
                self.evacuate_relaxed(i);
            }
        }
        for i in 0..self.cluster.strict.len() {
            if self.cluster.strict[i].evacuating {
                self.evacuate_strict(i);
            }
        }
    }

    fn evacuate_relaxed(&mut self, i: usize) {
        self.purge_cache(InstanceRef::Relaxed(i));
        if self.cluster.relaxed[i].offline_decoding.is_empty()
            && self.cluster.relaxed[i].inbound.is_empty()
            && !self.has_offline_prefilling(i)
        {
            return;
        }
        let mut victims = std::mem::take(&mut self.scratch_ids);
        victims.clear();
        {
            let node = &self.cluster.relaxed[i];
            let step = node.step.as_ref();
            victims.extend(node.offline_decoding.iter().copied().filter(
                |&r| step.map(|s| !s.involves(r)).unwrap_or(true),
            ));
        }
        for &rid in &victims {
            let kv = self.cluster.requests[rid as usize].kv_len() as u64;
            // Routes through Offload-to-staging when the transport
            // supports it — the evacuation win the notice buys.
            self.evict_offline_from_relaxed(i, rid);
            if self.cluster.kv_home[rid as usize] == KvHome::Staged {
                self.cluster.crash_evac_tokens += kv;
            }
        }
        // Partial prefill chains are not rescuable: recompute elsewhere.
        victims.clear();
        {
            let node = &self.cluster.relaxed[i];
            let step = node.step.as_ref();
            for &r in &node.prefilling {
                if !self.scheduled_online(r)
                    && step.map(|s| !s.involves(r)).unwrap_or(true)
                {
                    victims.push(r);
                }
            }
        }
        for &rid in &victims {
            self.evict_prefilling(i, rid);
        }
        // Inbound rescue/restore streams would land on a doomed instance:
        // cancel now instead of losing them at the crash.
        victims.clear();
        victims.extend(self.cluster.relaxed[i].inbound.iter().copied());
        for &rid in &victims {
            self.cancel_inbound_relaxed(i, rid);
        }
        self.scratch_ids = victims;
    }

    fn evacuate_strict(&mut self, i: usize) {
        self.purge_cache(InstanceRef::Strict(i));
        if self.cluster.strict[i].offline.is_empty()
            && self.cluster.strict[i].inbound.is_empty()
        {
            return;
        }
        let mut victims = std::mem::take(&mut self.scratch_ids);
        victims.clear();
        {
            let node = &self.cluster.strict[i];
            let step = node.step.as_ref();
            victims.extend(node.offline.iter().copied().filter(|&r| {
                step.map(|s| !s.involves(r)).unwrap_or(true)
            }));
        }
        for &rid in &victims {
            let kv = self.cluster.requests[rid as usize].kv_len() as u64;
            // Rescue to a live relaxed instance or host staging.
            self.evict_offline_from_strict(i, rid);
            match self.cluster.kv_home[rid as usize] {
                KvHome::Staged | KvHome::Relaxed(_) => {
                    self.cluster.crash_evac_tokens += kv;
                }
                _ => {}
            }
        }
        // In-flight offline inbound (Algorithm 1 migrations): recompute.
        victims.clear();
        {
            let node = &self.cluster.strict[i];
            for &r in &node.inbound {
                if !self.scheduled_online(r) {
                    victims.push(r);
                }
            }
        }
        for &rid in &victims {
            self.cancel_inbound_strict(i, rid);
        }
        self.scratch_ids = victims;
    }

    /// Tear down a crashed relaxed instance: every resident loses its KV.
    fn crash_relaxed(&mut self, i: usize) {
        self.abort_transition_for(PoolRole::Relaxed, i);
        self.cluster.router.set_down_relaxed(i, true);
        // The running step dies with the instance; its pending end event
        // goes stale through the seq guard.
        if let Some(step) = self.cluster.relaxed[i].step.take() {
            self.recycle_step(step);
        }
        self.purge_cache(InstanceRef::Relaxed(i));
        // Inbound rescue/restore streams: the reservation is gone and so
        // is the wire copy — recompute.
        let mut victims: Vec<RequestId> =
            self.cluster.relaxed[i].inbound.clone();
        for rid in victims.drain(..) {
            let kv = self.cluster.requests[rid as usize].kv_len() as u64;
            self.cancel_inbound_relaxed(i, rid);
            self.cluster.crash_evictions += 1;
            self.cluster.crash_recompute_tokens += kv;
        }
        // Queued online prefills hold no KV: re-route, nothing lost.
        self.reroute_online_queue(i);
        // Mid-prefill and offline decode residents: KV destroyed.
        victims.extend(self.cluster.relaxed[i].prefilling.iter().copied());
        victims
            .extend(self.cluster.relaxed[i].offline_decoding.iter().copied());
        for rid in victims.drain(..) {
            self.lose_kv_on_relaxed(i, rid);
        }
        // Requests parked in a strict `waiting_for_space` queue keep
        // their prefilled KV *here* without appearing in any local
        // queue: the crash costs them that KV too.
        for j in 0..self.cluster.strict.len() {
            let parked: Vec<RequestId> = self.cluster.strict[j]
                .waiting_for_space
                .iter()
                .copied()
                .filter(|&r| {
                    self.cluster.kv_home[r as usize] == KvHome::Relaxed(i)
                })
                .collect();
            for rid in parked {
                self.cluster.strict[j]
                    .waiting_for_space
                    .retain(|&r| r != rid);
                let kv = self.cluster.requests[rid as usize].kv_len();
                // Discharge the decode load the dispatch booked on `j`.
                self.cluster.router.decode_done(j, kv);
                self.lose_kv_on_relaxed(i, rid);
            }
        }
        // The releases above re-cached every prefix-registered block;
        // purge again so the corpse really holds nothing.
        self.purge_cache(InstanceRef::Relaxed(i));
        let node = &mut self.cluster.relaxed[i];
        node.down = true;
        node.evacuating = false;
        node.draining = false;
        debug_assert_eq!(
            node.kv.used_blocks(),
            0,
            "crashed relaxed instance must hold no KV"
        );
    }

    /// Tear down a crashed strict instance.
    fn crash_strict(&mut self, i: usize) {
        self.abort_transition_for(PoolRole::Strict, i);
        self.cluster.router.set_down_strict(i, true);
        if let Some(step) = self.cluster.strict[i].step.take() {
            self.recycle_step(step);
        }
        self.cluster.strict_step_meta[i] = None;
        self.purge_cache(InstanceRef::Strict(i));
        // Inbound transfers: an online dispatch's source KV was released
        // when the stream started, so the wire copy was the only one —
        // full recompute through a live relaxed instance. Offline
        // migrations fall back to the backlog.
        let inbound: Vec<RequestId> = self.cluster.strict[i].inbound.clone();
        for rid in inbound {
            let kv = self.cluster.requests[rid as usize].kv_len() as u64;
            if self.scheduled_online(rid) {
                self.cancel_inbound_online_strict(i, rid);
            } else {
                self.cancel_inbound_strict(i, rid);
            }
            self.cluster.crash_evictions += 1;
            self.cluster.crash_recompute_tokens += kv;
        }
        // Parked online admissions hold their KV on a relaxed instance:
        // unaffected by this crash, just re-dispatch them.
        self.redispatch_waiting(i);
        // Decode residents: KV destroyed. Online recomputes its full
        // context (prompt + tokens generated so far) on a live relaxed
        // instance; offline returns to the backlog.
        let mut victims: Vec<RequestId> =
            self.cluster.strict[i].online.clone();
        victims.extend(self.cluster.strict[i].offline.iter().copied());
        for rid in victims {
            self.lose_kv_on_strict(i, rid);
        }
        // The releases above re-cached every prefix-registered block;
        // purge again so the corpse really holds nothing.
        self.purge_cache(InstanceRef::Strict(i));
        let node = &mut self.cluster.strict[i];
        node.down = true;
        node.evacuating = false;
        node.draining = false;
        debug_assert_eq!(
            node.kv.used_blocks(),
            0,
            "crashed strict instance must hold no KV"
        );
    }

    /// `rid`'s KV on crashed relaxed instance `i` is destroyed: drop the
    /// residency, book the loss, and send the request back for recompute
    /// — online into a live relaxed instance's queue, offline to the
    /// backlog.
    fn lose_kv_on_relaxed(&mut self, i: usize, rid: RequestId) {
        let kv = self.cluster.requests[rid as usize].kv_len();
        self.cluster.relaxed[i].kv.release(rid).expect("resident kv");
        self.cluster.relaxed[i].prefilling.retain(|&r| r != rid);
        self.cluster.relaxed[i].offline_decoding.retain(|&r| r != rid);
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.evict_started[rid as usize] = f64::NAN;
        self.cluster.requests[rid as usize].evict();
        self.cluster.evictions += 1;
        self.cluster.crash_evictions += 1;
        self.cluster.crash_recompute_tokens += kv as u64;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Relaxed(i),
            req: rid,
        });
        if self.scheduled_online(rid) {
            let tokens = self.cluster.requests[rid as usize].recompute_len();
            let target = self.cluster.router.route_prefill(tokens);
            self.cluster.relaxed[target].online_queue.push_back(rid);
        } else {
            self.cluster.offline_backlog.push_back(rid);
        }
    }

    /// Strict-side counterpart of [`SchedulerCore::lose_kv_on_relaxed`].
    fn lose_kv_on_strict(&mut self, i: usize, rid: RequestId) {
        let kv = self.cluster.requests[rid as usize].kv_len();
        self.cluster.strict[i].kv.release(rid).expect("resident kv");
        self.cluster.strict[i].remove_online(rid);
        self.cluster.strict[i].remove_offline(rid);
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.evict_started[rid as usize] = f64::NAN;
        self.cluster.requests[rid as usize].evict();
        self.cluster.evictions += 1;
        self.cluster.crash_evictions += 1;
        self.cluster.crash_recompute_tokens += kv as u64;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Strict(i),
            req: rid,
        });
        if self.scheduled_online(rid) {
            let tokens = self.cluster.requests[rid as usize].recompute_len();
            let target = self.cluster.router.route_prefill(tokens);
            self.cluster.relaxed[target].online_queue.push_back(rid);
        } else {
            self.cluster.offline_backlog.push_back(rid);
        }
    }

    /// Crash path: an online dispatch was streaming into the crashed
    /// strict instance. Unlike the drain-time offline cancellation, the
    /// request must not fall into the offline backlog — it recomputes its
    /// full context through a live relaxed instance's online queue.
    fn cancel_inbound_online_strict(&mut self, inst: usize, rid: RequestId) {
        let job = self
            .transport
            .job_of(rid)
            .expect("inbound request has an active job");
        let cancelled =
            self.transport.cancel(job).expect("first cancel of active job");
        self.actions.push(Action::TransferCancel {
            job: cancelled.id,
            req: rid,
        });
        let kv_len = self.cluster.requests[rid as usize].kv_len();
        self.cluster.strict[inst].kv.release(rid).expect("reserved kv");
        self.cluster.strict[inst].inbound.retain(|&r| r != rid);
        // Discharge the decode load the dispatch booked on the router.
        self.cluster.router.decode_done(inst, kv_len);
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.evict_started[rid as usize] = f64::NAN;
        self.cluster.requests[rid as usize].evict();
        self.cluster.evictions += 1;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Strict(inst),
            req: rid,
        });
        let tokens = self.cluster.requests[rid as usize].recompute_len();
        let target = self.cluster.router.route_prefill(tokens);
        self.cluster.relaxed[target].online_queue.push_back(rid);
    }

    // ---------------------------------------------- prefix cache (§3.7)

    fn instance_mut(&mut self, inst: InstanceRef) -> &mut Instance {
        match inst {
            InstanceRef::Relaxed(i) => &mut self.cluster.relaxed[i],
            InstanceRef::Strict(i) => &mut self.cluster.strict[i],
        }
    }

    fn instance(&self, inst: InstanceRef) -> &Instance {
        match inst {
            InstanceRef::Relaxed(i) => &self.cluster.relaxed[i],
            InstanceRef::Strict(i) => &self.cluster.strict[i],
        }
    }

    /// Resolve `rid`'s declared shared prefix against an instance's cache
    /// (pure; empty when the cache is off or nothing is declared).
    fn peek_prefix(&self, inst: InstanceRef, rid: RequestId) -> PrefixMatch {
        if !self.cfg.serving.prefix.enabled {
            return PrefixMatch::empty();
        }
        let _p = obs::scope(Subsystem::Prefix);
        let req = &self.cluster.requests[rid as usize];
        let Some(p) = req.prefix else {
            return PrefixMatch::empty();
        };
        let want = p.len.min(req.recompute_len());
        if want == 0 {
            return PrefixMatch::empty();
        }
        let instance = self.instance(inst);
        instance.cache.lookup(p.family, want, &instance.kv)
    }

    /// Admit `rid`'s KV on `inst` with prefix sharing: reference the
    /// matched full blocks (zero recompute), copy-on-write a terminal
    /// partial, allocate the private remainder (the allocator LRU-reclaims
    /// cache blocks on demand; shared blocks are pinned first, so they can
    /// never be stolen). Fit must have been checked by the caller.
    fn admit_prefixed(
        &mut self,
        inst: InstanceRef,
        rid: RequestId,
        tokens: usize,
        m: &PrefixMatch,
    ) {
        let instance = self.instance_mut(inst);
        instance.kv.touch_blocks(&m.full_blocks);
        instance
            .kv
            .admit_shared(rid, tokens, &m.full_blocks, m.partial)
            .expect("fit checked");
        self.cluster.kv_home[rid as usize] = match inst {
            InstanceRef::Relaxed(i) => KvHome::Relaxed(i),
            InstanceRef::Strict(i) => KvHome::Strict(i),
        };
    }

    /// Record a prefill-admission cache resolution: counters, the planner's
    /// cache-adjusted load estimate, and the hit/miss notification.
    fn note_prefix_use(
        &mut self,
        inst: InstanceRef,
        rid: RequestId,
        m: &PrefixMatch,
        prompt_tokens: usize,
    ) {
        self.cluster.prefix_prompt_tokens += prompt_tokens as u64;
        // The planner sizes the *strict* pool from the online estimator,
        // and its footprint figure is prompt + half the output (KV at the
        // decode midpoint). Feed the share on exactly that population and
        // basis: online admissions only, cached prompt tokens over the
        // full per-request KV footprint — offline hit rates and unshared
        // output KV must not deflate the online capacity check.
        if self.scheduled_online(rid) {
            let kv_basis = prompt_tokens
                + self.cluster.requests[rid as usize].output_len / 2;
            self.pool.observe_prefix(m.cached_tokens, kv_basis.max(1));
        }
        if !self.cfg.serving.prefix.enabled
            || self.cluster.requests[rid as usize].prefix.is_none()
        {
            return;
        }
        self.cluster.prefix_lookups += 1;
        if m.cached_tokens > 0 {
            self.cluster.prefix_hits += 1;
            if self.scheduled_online(rid) {
                self.cluster.prefix_hit_tokens_online +=
                    m.cached_tokens as u64;
            } else {
                self.cluster.prefix_hit_tokens_offline +=
                    m.cached_tokens as u64;
            }
        }
        self.actions.push(Action::PrefixResolve {
            inst,
            req: rid,
            cached_tokens: m.cached_tokens,
            cached_blocks: m.cached_blocks(),
        });
    }

    /// Register `rid`'s freshly materialized prefix chain in `inst`'s
    /// cache (prefill completion, or a transfer landing at a new home).
    /// Draining instances take no new cache entries.
    fn register_prefix(&mut self, inst: InstanceRef, rid: RequestId) {
        if !self.cfg.serving.prefix.enabled {
            return;
        }
        let _p = obs::scope(Subsystem::Prefix);
        let Some(p) = self.cluster.requests[rid as usize].prefix else {
            return;
        };
        let instance = self.instance_mut(inst);
        if !instance.accepts_work() {
            return;
        }
        let upto = p.len.min(instance.kv.tokens_of(rid));
        if upto == 0 {
            return;
        }
        let Some(blocks) = instance.kv.blocks_of(rid).map(|b| b.to_vec())
        else {
            return;
        };
        let Instance { cache, kv, .. } = instance;
        cache.insert(p.family, upto, &blocks, kv);
    }

    /// Sync allocator-side LRU reclaims back into the prefix indexes and
    /// emit the evict notifications. Runs once per entry point, after all
    /// decisions (stale index entries are validated away in the meantime).
    fn flush_cache_events(&mut self) {
        if !self.cfg.serving.prefix.enabled {
            return;
        }
        let _p = obs::scope(Subsystem::Prefix);
        for i in 0..self.cluster.relaxed.len() {
            self.flush_cache_on(InstanceRef::Relaxed(i));
        }
        for i in 0..self.cluster.strict.len() {
            self.flush_cache_on(InstanceRef::Strict(i));
        }
    }

    fn flush_cache_on(&mut self, inst: InstanceRef) {
        let instance = self.instance_mut(inst);
        let reclaimed = instance.kv.take_reclaimed();
        if reclaimed.is_empty() {
            return;
        }
        let Instance { cache, kv, .. } = instance;
        let extra = cache.forget_blocks(&reclaimed, kv);
        let blocks = reclaimed.len() + extra;
        self.cluster.prefix_evicted_blocks += blocks as u64;
        self.actions.push(Action::PrefixEvict { inst, blocks });
    }

    /// Drop every cache entry on a draining instance (run at drain start
    /// and on every drain tick, since releases keep re-caching blocks
    /// until the residents are gone).
    fn purge_cache(&mut self, inst: InstanceRef) {
        if !self.cfg.serving.prefix.enabled {
            return;
        }
        let instance = self.instance_mut(inst);
        if instance.cache.is_empty() {
            return;
        }
        let Instance { cache, kv, .. } = instance;
        let blocks = cache.purge(kv);
        // Purged entries were dropped directly; clear any allocator log
        // for them so the flush does not double-forget.
        let _ = kv.take_reclaimed();
        if blocks > 0 {
            self.cluster.prefix_evicted_blocks += blocks as u64;
            self.actions.push(Action::PrefixEvict { inst, blocks });
        }
    }

    /// Snapshot the prefix-cache metrics (DESIGN.md §3.7).
    pub fn prefix_report(&self) -> PrefixReport {
        let c = &self.cluster;
        let saved =
            c.prefix_hit_tokens_online + c.prefix_hit_tokens_offline;
        let cow: u64 = c
            .relaxed
            .iter()
            .chain(&c.strict)
            .map(|i| i.kv.cow_copies)
            .sum();
        PrefixReport {
            enabled: self.cfg.serving.prefix.enabled,
            lookups: c.prefix_lookups,
            hits: c.prefix_hits,
            hit_rate: saved as f64 / c.prefix_prompt_tokens.max(1) as f64,
            prefill_tokens_saved: saved,
            online_tokens_saved: c.prefix_hit_tokens_online,
            offline_tokens_saved: c.prefix_hit_tokens_offline,
            transfer_tokens_saved: c.transfer_tokens_saved,
            cow_copies: cow,
            evicted_blocks: c.prefix_evicted_blocks,
            reclaimed_block_s: c.cache_block_seconds(self.now),
            cached_blocks_final: c.reclaimable_cache_blocks(),
        }
    }

    /// Snapshot the chunked-prefill iteration metrics (DESIGN.md §3.8).
    pub fn chunk_report(&self) -> ChunkReport {
        let c = &self.cluster;
        ChunkReport {
            enabled: self.cfg.serving.chunk_tokens.is_enabled(),
            mode: self.cfg.serving.chunk_tokens.to_string(),
            steps: c.chunk_steps,
            mixed_steps: c.chunk_mixed_steps,
            prefill_chunks: c.chunk_segments,
            prefill_tokens: c.chunk_prefill_tokens,
            budget_offered_tokens: c.chunk_budget_offered,
            budget_utilization: if c.chunk_budget_offered == 0 {
                0.0
            } else {
                c.chunk_prefill_tokens as f64
                    / c.chunk_budget_offered as f64
            },
            interference_delay_s: c.chunk_interference_s,
            preemptions: c.preemptions,
            preempted_work_retained: c.chunk_retained_tokens,
            preempted_work_discarded: c.chunk_discarded_tokens,
            accounting_errors: c.chunk_accounting_errors,
        }
    }

    // ------------------------------------------------------- transport glue

    /// Enqueue a transfer of `kv_tokens` of `rid`'s KV on the transport
    /// engine and emit the start notification plus any immediate chunk
    /// orders. `kv_tokens` may be less than the request's full KV when the
    /// destination already holds its prefix blocks (only non-resident
    /// blocks move — DESIGN.md §3.7).
    fn enqueue_transfer(
        &mut self,
        rid: RequestId,
        kind: TransferKind,
        kv_tokens: usize,
    ) {
        let kv_tokens = kv_tokens.max(1);
        let (job, orders) =
            self.transport.enqueue(self.now, rid, kind, kv_tokens);
        self.actions.push(Action::TransferStart {
            job,
            req: rid,
            kind,
            kv_tokens,
            chunks: self.transport.chunks_per_job(),
        });
        self.emit_chunk_orders(orders);
    }

    /// Transfer volume after destination-resident prefix dedup, recording
    /// the saving.
    fn transfer_tokens_for(
        &mut self,
        rid: RequestId,
        m: &PrefixMatch,
    ) -> usize {
        let full = self.cluster.requests[rid as usize].kv_len();
        if m.cached_tokens > 0 {
            let moved = full.saturating_sub(m.cached_tokens).max(1);
            self.cluster.transfer_tokens_saved += (full - moved) as u64;
            moved
        } else {
            full
        }
    }

    fn emit_chunk_orders(&mut self, orders: Vec<ChunkOrder>) {
        for o in orders {
            self.actions.push(Action::TransferChunk {
                job: o.job,
                req: o.req,
                link: o.link,
                chunk: o.chunk,
                predicted_latency: o.duration,
                seq: o.seq,
            });
        }
    }

    /// Hand the moved KV to its destination once the last chunk landed.
    fn land_transfer(&mut self, job: TransferJob) {
        let rid = job.req;
        match job.kind {
            TransferKind::Dispatch { to_strict }
            | TransferKind::Migrate { to_strict } => {
                self.decode_handoff(rid, to_strict);
            }
            TransferKind::Rescue { to_relaxed }
            | TransferKind::Restore { to_relaxed } => {
                self.cluster.relaxed[to_relaxed]
                    .inbound
                    .retain(|&r| r != rid);
                self.cluster.requests[rid as usize].phase = Phase::Decoding;
                self.cluster.relaxed[to_relaxed].offline_decoding.push(rid);
                // The landed chain is cacheable content at its new home.
                self.register_prefix(InstanceRef::Relaxed(to_relaxed), rid);
                if matches!(job.kind, TransferKind::Restore { .. }) {
                    self.cluster.restores += 1;
                }
                let started = self.cluster.evict_started[rid as usize];
                if started.is_finite() {
                    self.cluster.restart_latency.record(self.now - started);
                    self.cluster.evict_started[rid as usize] = f64::NAN;
                }
                if self.cluster.relaxed[to_relaxed].is_idle() {
                    self.start_relaxed_step(to_relaxed);
                }
            }
            TransferKind::Offload => {
                self.cluster.staged_offline.push_back(rid);
                // Space may already exist somewhere in the relaxed pool.
                self.try_restores();
            }
        }
    }

    /// Stream staged KV back into the relaxed pool wherever space permits
    /// (keeping the same online-prefill headroom the gating path reserves).
    /// Prefix blocks already resident at the destination are shared, not
    /// re-streamed.
    fn try_restores(&mut self) {
        for inst in 0..self.cluster.relaxed.len() {
            if !self.cluster.relaxed[inst].accepts_work() {
                continue; // no restores onto a draining/doomed/down node
            }
            while let Some(&rid) = self.cluster.staged_offline.front() {
                let need =
                    self.cluster.requests[rid as usize].kv_len() + 1;
                if self.cluster.relaxed[inst].kv.free_tokens()
                    < need + ONLINE_PREFILL_RESERVE_TOKENS
                {
                    break;
                }
                self.cluster.staged_offline.pop_front();
                let m = self.peek_prefix(InstanceRef::Relaxed(inst), rid);
                self.admit_prefixed(InstanceRef::Relaxed(inst), rid, need, &m);
                self.cluster.relaxed[inst].inbound.push(rid);
                let moved = self.transfer_tokens_for(rid, &m);
                self.enqueue_transfer(
                    rid,
                    TransferKind::Restore { to_relaxed: inst },
                    moved,
                );
            }
            if self.cluster.staged_offline.is_empty() {
                break;
            }
        }
    }

    /// Aggregate transport metrics over an observation window.
    pub fn transport_report(&self, window_s: f64) -> TransportReport {
        let links = self
            .transport
            .links()
            .iter()
            .map(|l| LinkReport {
                name: l.spec.name.clone(),
                bytes_moved: l.bytes_moved,
                busy_s: l.busy_s,
                utilization: l.utilization(window_s),
                jobs_completed: l.jobs_completed,
                stall_s: l.stall_s,
            })
            .collect::<Vec<_>>();
        TransportReport {
            stall_s: links.iter().map(|l| l.stall_s).sum(),
            links,
            rescues: self.cluster.rescues,
            offloads: self.cluster.offloads,
            restores: self.cluster.restores,
            restart_latency: self.cluster.restart_latency.summary(),
            bytes_enqueued: self.transport.bytes_enqueued,
            bytes_delivered: self.transport.bytes_delivered,
            jobs_cancelled: self.transport.jobs_cancelled,
        }
    }

    // ------------------------------------------------- elastic pool manager

    /// Pool-manager heartbeat, run at the end of every entry point: advance
    /// the in-flight role transition, and — when none is in flight — ask
    /// the planner for a repartition plan and start a transition toward it.
    /// Epochs are evaluated lazily at entry-point granularity; with
    /// millisecond-scale step events this is indistinguishable from a
    /// timer, and it keeps the executors free of pool-specific work orders.
    fn pool_tick(&mut self) {
        let _p = obs::scope(Subsystem::Pool);
        // Crash-noticed instances keep streaming KV off every tick until
        // the crash fires (no-op without an active notice).
        self.evacuation_tick();
        self.advance_transition();
        if self.pool.transition.is_some() {
            return;
        }
        // Plan around crashed instances: the planner sees live capacity,
        // not nominal pool sizes (DESIGN.md §3.9).
        let n_relaxed = self
            .cluster
            .relaxed
            .iter()
            .filter(|r| !r.down)
            .count()
            .max(1);
        let n_strict = self
            .cluster
            .strict
            .iter()
            .filter(|s| !s.down)
            .count()
            .max(1);
        let slo = self.cfg.serving.slo;
        let Some(plan) =
            self.pool.replan(self.now, &self.pm, &slo, n_relaxed, n_strict)
        else {
            return;
        };
        self.actions.push(Action::RepartitionPlan {
            epoch: plan.epoch,
            relaxed_current: n_relaxed,
            strict_current: n_strict,
            relaxed_target: plan.relaxed_target,
            strict_target: plan.strict_target,
        });
        // One transition at a time, always from the tail of the shrinking
        // pool (index stability of everything else); the next re-plan keeps
        // moving if one step was not enough. A down or evacuating tail
        // cannot drain — wait for recovery or a later plan.
        if plan.strict_target > n_strict
            && n_relaxed > 1
            && self.cluster.relaxed.last().is_some_and(|r| r.accepts_work())
        {
            self.start_drain(PoolRole::Relaxed);
        } else if plan.strict_target < n_strict
            && n_strict > 1
            && self.cluster.strict.last().is_some_and(|s| s.accepts_work())
        {
            self.start_drain(PoolRole::Strict);
        }
    }

    /// Begin draining the tail instance of `from` for a role flip.
    fn start_drain(&mut self, from: PoolRole) {
        let t = match from {
            PoolRole::Relaxed => {
                let i = self.cluster.relaxed.len() - 1;
                self.cluster.relaxed[i].draining = true;
                self.cluster.router.set_drain_relaxed(Some(i));
                self.actions.push(Action::RoleChange {
                    phase: RolePhase::Drain,
                    inst: InstanceRef::Relaxed(i),
                    to: PoolRole::Strict,
                });
                // Cached blocks are `used` capacity to the flip check:
                // drop them now (and on every drain tick below).
                self.purge_cache(InstanceRef::Relaxed(i));
                Transition::drain(from, i, self.now)
            }
            PoolRole::Strict => {
                let i = self.cluster.strict.len() - 1;
                self.cluster.strict[i].draining = true;
                self.cluster.router.set_drain_strict(Some(i));
                self.actions.push(Action::RoleChange {
                    phase: RolePhase::Drain,
                    inst: InstanceRef::Strict(i),
                    to: PoolRole::Relaxed,
                });
                self.purge_cache(InstanceRef::Strict(i));
                // Online admissions parked on the draining instance would
                // wait forever (it frees no space for new work): re-route
                // them to the surviving pool now.
                self.redispatch_waiting(i);
                Transition::drain(from, i, self.now)
            }
        };
        self.pool.transition = Some(t);
        self.drain_evictions(t);
    }

    /// Move resident offline KV off the draining instance through the
    /// recoverable-eviction transport paths, and cancel in-flight inbound
    /// reservations. Online residents are left to finish decoding in place
    /// — a role flip must never violate an online SLO. Step participants
    /// are skipped (eviction only acts between iterations); they become
    /// evictable at the next tick once their step completed and the
    /// draining instance starts no new decode steps.
    fn drain_evictions(&mut self, t: Transition) {
        let i = t.inst;
        match t.from {
            PoolRole::Relaxed => {
                // Releases since the last tick may have re-cached blocks;
                // the drain keeps the cache empty so the flip check sees
                // only pinned capacity.
                self.purge_cache(InstanceRef::Relaxed(i));
                // Cheap no-op on the event-dense common case: the tick
                // runs at every entry point while draining.
                if self.cluster.relaxed[i].offline_decoding.is_empty()
                    && self.cluster.relaxed[i].inbound.is_empty()
                    && !self.has_offline_prefilling(i)
                {
                    return;
                }
                // Victims collected into the reusable scratch (hot path:
                // runs at every entry point while draining); step
                // participants are checked in place, not cloned.
                let mut victims = std::mem::take(&mut self.scratch_ids);
                victims.clear();
                {
                    let node = &self.cluster.relaxed[i];
                    let step = node.step.as_ref();
                    victims.extend(
                        node.offline_decoding.iter().copied().filter(|&r| {
                            step.map(|s| !s.involves(r)).unwrap_or(true)
                        }),
                    );
                }
                for &rid in &victims {
                    self.evict_offline_from_relaxed(i, rid);
                }
                // Offline mid-prefill residents: partial chains are not
                // rescuable — discard for recompute elsewhere.
                victims.clear();
                {
                    let node = &self.cluster.relaxed[i];
                    let step = node.step.as_ref();
                    for &r in &node.prefilling {
                        if !self.scheduled_online(r)
                            && step.map(|s| !s.involves(r)).unwrap_or(true)
                        {
                            victims.push(r);
                        }
                    }
                }
                for &rid in &victims {
                    self.evict_prefilling(i, rid);
                }
                victims.clear();
                victims.extend(self.cluster.relaxed[i].inbound.iter().copied());
                for &rid in &victims {
                    self.cancel_inbound_relaxed(i, rid);
                }
                self.scratch_ids = victims;
            }
            PoolRole::Strict => {
                self.purge_cache(InstanceRef::Strict(i));
                if self.cluster.strict[i].offline.is_empty()
                    && self.cluster.strict[i].inbound.is_empty()
                {
                    return;
                }
                let mut victims = std::mem::take(&mut self.scratch_ids);
                victims.clear();
                {
                    let node = &self.cluster.strict[i];
                    let step = node.step.as_ref();
                    victims.extend(node.offline.iter().copied().filter(
                        |&r| step.map(|s| !s.involves(r)).unwrap_or(true),
                    ));
                }
                for &rid in &victims {
                    self.evict_offline_from_strict(i, rid);
                }
                // Abort in-flight *offline* inbound streams (Algorithm 1
                // migrations) so the drain need not wait for — and then
                // immediately re-evict — KV that is still on the wire.
                // Online dispatches ride out and decode in place: a
                // cancelled online KV would force a recompute and risk the
                // very SLO violation the drain contract forbids.
                victims.clear();
                {
                    let node = &self.cluster.strict[i];
                    for &r in &node.inbound {
                        if !self.scheduled_online(r) {
                            victims.push(r);
                        }
                    }
                }
                for &rid in &victims {
                    self.cancel_inbound_strict(i, rid);
                }
                self.scratch_ids = victims;
            }
        }
    }

    /// Abort an in-flight offline migration into a draining strict
    /// instance. Mirrors [`SchedulerCore::cancel_inbound_relaxed`]: the
    /// transport releases the job exactly once and the request falls back
    /// to discard-and-recompute.
    fn cancel_inbound_strict(&mut self, inst: usize, rid: RequestId) {
        let job = self
            .transport
            .job_of(rid)
            .expect("inbound request has an active job");
        let cancelled =
            self.transport.cancel(job).expect("first cancel of active job");
        self.actions.push(Action::TransferCancel {
            job: cancelled.id,
            req: rid,
        });
        let kv_len = self.cluster.requests[rid as usize].kv_len();
        self.cluster.strict[inst].kv.release(rid).expect("reserved kv");
        self.cluster.strict[inst].inbound.retain(|&r| r != rid);
        self.cluster.router.decode_done(inst, kv_len);
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.evict_started[rid as usize] = f64::NAN;
        self.cluster.requests[rid as usize].evict();
        self.cluster.offline_backlog.push_back(rid);
        self.cluster.evictions += 1;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Strict(inst),
            req: rid,
        });
        self.kick_idle_relaxed();
    }

    /// Re-route online requests parked for space on a draining strict
    /// instance to the rest of the strict pool.
    fn redispatch_waiting(&mut self, inst: usize) {
        let waiting: Vec<RequestId> = self.cluster.strict[inst]
            .waiting_for_space
            .drain(..)
            .collect();
        for rid in waiting {
            let kv_len = self.cluster.requests[rid as usize].kv_len();
            // Discharge the load the original routing attributed here.
            self.cluster.router.decode_done(inst, kv_len);
            let from = match self.cluster.kv_home[rid as usize] {
                KvHome::Relaxed(i) => i,
                _ => unreachable!("waiting request KV must be on relaxed"),
            };
            let target = self.cluster.router.route_decode(kv_len);
            self.try_dispatch_to_strict(rid, from, target);
        }
    }

    /// Drive the in-flight transition: keep evicting while draining, and
    /// flip + begin the warm step the moment the instance is empty.
    fn advance_transition(&mut self) {
        let Some(t) = self.pool.transition else {
            return;
        };
        if t.phase != TransitionPhase::Drain {
            return; // warm completion arrives via the warm step's end
        }
        self.drain_evictions(t);
        let drained = match t.from {
            PoolRole::Relaxed => {
                self.cluster.relaxed[t.inst].drained_for_flip()
            }
            PoolRole::Strict => self.cluster.strict[t.inst].drained_for_flip(),
        };
        if !drained {
            return;
        }
        let strict_before = self.cluster.strict.len();
        // Close the per-role instance-seconds integral at the old sizes.
        self.cluster.accrue_role_seconds(self.now);
        let new_ref = match t.from {
            PoolRole::Relaxed => {
                InstanceRef::Strict(self.cluster.flip_relaxed_to_strict())
            }
            PoolRole::Strict => {
                InstanceRef::Relaxed(self.cluster.flip_strict_to_relaxed())
            }
        };
        self.pool.on_flip(self.now, strict_before);
        let new_idx = match new_ref {
            InstanceRef::Relaxed(i) | InstanceRef::Strict(i) => i,
        };
        self.pool.transition = Some(Transition {
            from: t.from,
            inst: new_idx,
            phase: TransitionPhase::Warm,
            started: t.started,
        });
        self.actions.push(Action::RoleChange {
            phase: RolePhase::Flip,
            inst: new_ref,
            to: t.to(),
        });
        self.begin_warm(new_ref);
    }

    /// Occupy the freshly flipped instance with a [`StepKind::Warm`] step:
    /// an ordinary timed work order, so both executors drive the warm-up
    /// without pool-specific machinery.
    fn begin_warm(&mut self, inst_ref: InstanceRef) {
        let seq = self.cluster.alloc_seq();
        let inst = match inst_ref {
            InstanceRef::Relaxed(i) => &mut self.cluster.relaxed[i],
            InstanceRef::Strict(i) => &mut self.cluster.strict[i],
        };
        inst.step = Some(Step {
            kind: StepKind::Warm,
            started: self.now,
            ends: self.now + WARMUP_S,
            participants: Vec::new(),
            prefill: Vec::new(),
            seq,
            preempted: false,
        });
        self.actions.push(Action::StartStep {
            inst: inst_ref,
            kind: StepKind::Warm,
            participants: Vec::new(),
            prefill: Vec::new(),
            predicted_latency: WARMUP_S,
            cached_tokens: 0,
            seq,
        });
    }

    /// The warm step ended: the transition is complete and the instance
    /// serves its new pool from here on.
    fn complete_warm(&mut self, inst_ref: InstanceRef) {
        let to = match &self.pool.transition {
            Some(t) if t.phase == TransitionPhase::Warm => t.to(),
            _ => return,
        };
        self.pool.on_warm_done(self.now);
        self.actions.push(Action::RoleChange {
            phase: RolePhase::Warm,
            inst: inst_ref,
            to,
        });
    }

    /// Snapshot the pool-manager metrics (per-epoch pool sizes, transition
    /// durations, stranded capacity).
    pub fn pool_report(&self) -> PoolReport {
        self.pool.report(
            self.now,
            self.cluster.relaxed.len(),
            self.cluster.strict.len(),
        )
    }

    // ------------------------------------------------------------ arrivals

    /// Is this request scheduled as "online" by the active policy?
    /// (`base P/D` treats offline requests as ordinary online requests.)
    fn scheduled_online(&self, rid: RequestId) -> bool {
        self.cluster.requests[rid as usize].class.is_online()
            || self.cfg.policy == Policy::BasePd
    }

    /// Any offline-scheduled mid-prefill resident on relaxed `inst`?
    fn has_offline_prefilling(&self, inst: usize) -> bool {
        self.cluster.relaxed[inst]
            .prefilling
            .iter()
            .any(|&r| !self.scheduled_online(r))
    }

    fn arrival(&mut self, rid: RequestId) {
        if self.scheduled_online(rid) {
            let prompt = self.cluster.requests[rid as usize].prompt_len;
            let inst = self.cluster.router.route_prefill(prompt);
            self.cluster.relaxed[inst].online_queue.push_back(rid);
            if self.chunk_enabled() {
                // Chunk-granular fast preemption (§3.4.1, DESIGN.md
                // §3.8): composed iterations are latency-bounded, so the
                // arrival just halts offline chunk scheduling at the next
                // boundary — completed progress is retained by the
                // cursors instead of discarded.
                self.note_chunk_preemption(inst);
            } else {
                self.maybe_preempt(inst);
            }
            if self.cluster.relaxed[inst].is_idle() {
                self.start_relaxed_step(inst);
            }
        } else {
            self.cluster.offline_backlog.push_back(rid);
            self.kick_idle_relaxed();
        }
    }

    /// An online arrival found offline prefill chunks in flight on `inst`:
    /// record the chunk-granular preemption (the §3.8 counterpart of the
    /// exclusive-step truncation) and the *computed* prefill progress the
    /// cursors retain — exactly the work the discard-and-recompute
    /// baseline would have thrown away at this moment (cumulative across
    /// events by design: the baseline restarts from scratch after every
    /// truncation, so each event books the full would-be recompute).
    /// Latched per step via `Step::preempted` (mirroring the
    /// exclusive-step latch), so a burst of arrivals during one iteration
    /// counts once.
    fn note_chunk_preemption(&mut self, inst: usize) {
        if !self.cfg.policy.preempts_offline_prefill() {
            return;
        }
        let (hit, retained) = {
            let Some(step) = self.cluster.relaxed[inst].step.as_ref() else {
                return;
            };
            if step.preempted {
                return; // already counted for this iteration
            }
            let mut hit = false;
            let mut retained = 0usize;
            for s in &step.prefill {
                if !self.scheduled_online(s.req) {
                    hit = true;
                    retained += self.cluster.requests[s.req as usize]
                        .computed_prefill();
                }
            }
            (hit, retained)
        };
        if hit {
            let step = self.cluster.relaxed[inst]
                .step
                .as_mut()
                .expect("checked above");
            step.preempted = true;
            self.cluster.preemptions += 1;
            self.cluster.chunk_retained_tokens += retained as u64;
        }
    }

    /// Truncate a running offline prefill at the next layer boundary
    /// (§3.4.1 layer-level interruption).
    fn maybe_preempt(&mut self, inst: usize) {
        if !self.cfg.policy.preempts_offline_prefill() {
            return;
        }
        let now = self.now;
        let Some(step) = self.cluster.relaxed[inst].step.as_ref() else {
            return;
        };
        if step.kind != StepKind::PrefillOffline || step.preempted {
            return;
        }
        let span = (step.ends - step.started).max(1e-9);
        let elapsed_frac = ((now - step.started) / span).clamp(0.0, 1.0);
        let mean_prompt = (step
            .participants
            .iter()
            .map(|&r| self.cluster.requests[r as usize].recompute_len())
            .sum::<usize>()
            / step.participants.len().max(1))
        .max(1);
        let delay = preemption_delay(&self.pm, mean_prompt, elapsed_frac);
        let new_end = now + delay;
        if new_end >= step.ends {
            return;
        }
        // Work actually performed before the truncation point — what the
        // discard-and-recompute throws away (the §3.8 chunked model's
        // `preempted_work_retained` counterpart).
        let discarded: f64 = step
            .participants
            .iter()
            .map(|&r| {
                self.cluster.requests[r as usize].remaining_prefill() as f64
            })
            .sum::<f64>()
            * elapsed_frac;
        let seq = self.cluster.alloc_seq();
        let step = self.cluster.relaxed[inst].step.as_mut().expect("checked");
        step.ends = new_end;
        step.preempted = true;
        step.seq = seq;
        self.actions.push(Action::Preempt { inst, delay, seq });
        self.cluster.preemptions += 1;
        self.cluster.chunk_discarded_tokens += discarded as u64;
    }

    fn kick_idle_relaxed(&mut self) {
        for i in 0..self.cluster.relaxed.len() {
            if self.cluster.relaxed[i].is_idle() {
                self.start_relaxed_step(i);
                if !self.cluster.relaxed[i].is_idle() {
                    return;
                }
            }
        }
    }

    // ----------------------------------------------------- relaxed stepping

    fn start_relaxed_step(&mut self, inst: usize) {
        // Step boundaries are also when staged KV gets to stream back in
        // (restores are transfers — they do not occupy the instance).
        self.try_restores();
        if self.cluster.relaxed[inst].down
            || !self.cluster.relaxed[inst].is_idle()
        {
            return;
        }
        if self.chunk_enabled() {
            self.compose_relaxed_step(inst);
            return;
        }
        // Exclusive-step mode (`chunk_tokens = off`): an iteration is a
        // whole prefill batch *or* a decode batch — the pre-§3.8 model,
        // kept as the refactor's differential baseline.
        if self.start_online_prefill(inst) {
            return;
        }
        if self.start_offline_prefill(inst) {
            return;
        }
        self.start_relaxed_decode(inst);
    }

    // ----------------------------------- chunked composition (§3.8)

    fn chunk_enabled(&self) -> bool {
        self.cfg.serving.chunk_tokens.is_enabled()
    }

    /// Per-iteration chunk budget over the instance's current decode
    /// batch: solver-chosen under `auto` (largest chunk keeping the
    /// composed iteration inside the headroom-reduced TPOT budget,
    /// floored at the progress quantum), fixed otherwise.
    fn chunk_budget_for(&self, stats: BatchStats) -> usize {
        let cap = self.cfg.serving.sched.prefill_token_budget.max(1);
        match self.cfg.serving.chunk_tokens {
            ChunkMode::Off => 0,
            ChunkMode::Fixed(n) => n.clamp(1, cap),
            ChunkMode::Auto => {
                let budget = self.cfg.serving.slo.tpot
                    * (1.0 - self.cfg.serving.sched.slo_margin);
                self.pm
                    .chunk_budget(stats, budget, cap)
                    .max(MIN_CHUNK_TOKENS.min(cap))
            }
        }
    }

    /// The §3.8 batch-composer — the single replacement for the exclusive
    /// `start_online_prefill`/`start_offline_prefill`/`start_relaxed_decode`
    /// trio: every iteration carries decode tokens for all offline
    /// residents plus up to the chunk budget of prefill work drawn from
    /// per-request progress cursors. Online prefill work fills the budget
    /// first; offline chunks are scheduled only while no online prefill is
    /// pending (chunk-granular fast preemption), and new offline
    /// admissions still pass the §3.4.2 gating priced at their *remaining
    /// uncached* tokens.
    fn compose_relaxed_step(&mut self, inst: usize) {
        // Draining for a flip and evacuating ahead of a crash behave the
        // same here: no new offline work, residents stream off between
        // iterations.
        let no_new_work = !self.cluster.relaxed[inst].accepts_work();
        // Does this iteration actually carry a decode side? Parked
        // residents under `online priority` (hold KV, never decode here)
        // and a draining instance's residents must not be priced as
        // phantom decode work — that would both inflate the predicted
        // latency and collapse the auto budget to its floor.
        let decodes_here =
            !no_new_work && self.cfg.policy.offline_decode_on_relaxed();
        // Budget from the pre-admission decode batch (admissions below may
        // evict residents, which only loosens the bound). Steady-state
        // decode iterations with no prefill candidate anywhere skip the
        // solver entirely — it sits on the hottest loop in the simulator.
        let any_prefill = !self.cluster.relaxed[inst].prefilling.is_empty()
            || !self.cluster.relaxed[inst].online_queue.is_empty()
            || !self.cluster.offline_backlog.is_empty();
        let budget = if any_prefill {
            let stats0 = if decodes_here {
                self.relaxed_pool_stats(inst)
            } else {
                BatchStats::empty()
            };
            self.chunk_budget_for(stats0)
        } else {
            0
        };
        let mut segs = self.pooled_segs();
        let mut used = 0usize;
        let mut cached_total = 0usize;

        // 1. Resume online mid-prefill residents, admission order.
        let mut resident = std::mem::take(&mut self.scratch_ids);
        resident.clear();
        resident.extend(self.cluster.relaxed[inst].prefilling.iter().copied());
        for &rid in &resident {
            if used >= budget {
                break;
            }
            if !self.scheduled_online(rid) {
                continue;
            }
            if let Some(seg) = self.schedule_chunk(inst, rid, budget - used)
            {
                used += seg.tokens;
                segs.push(seg);
            }
        }

        // 2. Admit new online arrivals into the composition (head-of-queue
        // rejection semantics match the exclusive-step path).
        while used < budget {
            let Some(&rid) =
                self.cluster.relaxed[inst].online_queue.front()
            else {
                break;
            };
            match self.admit_chunked_online(inst, rid, budget - used) {
                AdmitChunk::Scheduled(seg, cached) => {
                    self.cluster.relaxed[inst].online_queue.pop_front();
                    used += seg.tokens;
                    cached_total += cached;
                    segs.push(seg);
                }
                AdmitChunk::Rejected => {
                    // Cannot fit even after eviction: drop, keep going.
                    self.cluster.relaxed[inst].online_queue.pop_front();
                    self.cluster.requests[rid as usize].phase =
                        Phase::Finished;
                    self.actions.push(Action::Complete { req: rid });
                }
                AdmitChunk::NoSpace => break,
            }
        }

        // 3. Offline chunks — only while no online prefill work is
        // pending (an online arrival halts offline chunk scheduling at
        // the iteration boundary) and the instance is not draining.
        let online_pending = !segs.is_empty()
            || !self.cluster.relaxed[inst].online_queue.is_empty();
        let offline_ok = !no_new_work
            && (!online_pending || !self.cfg.policy.offline_idle_only());
        if offline_ok {
            for &rid in &resident {
                if used >= budget {
                    break;
                }
                if self.scheduled_online(rid) {
                    continue;
                }
                if let Some(seg) =
                    self.schedule_chunk(inst, rid, budget - used)
                {
                    used += seg.tokens;
                    segs.push(seg);
                }
            }
            while used < budget {
                let Some(&rid) = self.cluster.offline_backlog.front()
                else {
                    break;
                };
                match self.admit_chunked_offline(inst, rid, budget - used) {
                    AdmitChunk::Scheduled(seg, cached) => {
                        self.cluster.offline_backlog.pop_front();
                        used += seg.tokens;
                        cached_total += cached;
                        segs.push(seg);
                        self.actions.push(Action::Admit { inst, req: rid });
                    }
                    AdmitChunk::Rejected | AdmitChunk::NoSpace => break,
                }
            }
        }
        self.scratch_ids = resident;

        // A later admission's eviction may have displaced a resident whose
        // segment was already scheduled this composition (offline discard
        // or online overcommit requeue): drop those stale segments so the
        // step neither prices nor executes work for departed requests.
        // (`cached_total` stays as admitted — the admission-time cache
        // counters already ran, and the stream invariant compares against
        // exactly those.)
        segs.retain(|s| {
            self.cluster.kv_home[s.req as usize] == KvHome::Relaxed(inst)
                && self.cluster.requests[s.req as usize].phase
                    == Phase::Prefilling
        });
        let used: usize = segs.iter().map(|s| s.tokens).sum();

        // 4. Decode side: every offline decode resident (post-eviction
        // view — admissions above may have reclaimed space).
        let mut decode = self.pooled_ids();
        if decodes_here {
            decode
                .extend_from_slice(&self.cluster.relaxed[inst].offline_decoding);
        }
        if decode.is_empty() && segs.is_empty() {
            // Nothing to run; instance stays idle. Hand the (empty)
            // buffers straight back.
            self.recycle_ids(decode);
            self.recycle_segs(segs);
            return;
        }

        // Price the iteration with the decode work it actually performs
        // (parked residents hold KV but run nothing).
        let stats = if decodes_here {
            self.relaxed_pool_stats(inst)
        } else {
            BatchStats::empty()
        };
        let latency = self.pm.mixed_iter_cost(stats, used).latency_s;
        self.cluster.chunk_steps += 1;
        if !decode.is_empty() && !segs.is_empty() {
            self.cluster.chunk_mixed_steps += 1;
            self.cluster.chunk_interference_s +=
                (latency - self.pm.decode_latency(stats)).max(0.0);
        }
        if !segs.is_empty() {
            self.cluster.chunk_segments += segs.len() as u64;
            self.cluster.chunk_prefill_tokens += used as u64;
            self.cluster.chunk_budget_offered += budget as u64;
        }

        self.begin_relaxed_step_composed(
            inst,
            StepKind::Composed,
            decode,
            segs,
            latency,
            cached_total,
        );
    }

    /// Schedule the next chunk of an already-resident mid-prefill request:
    /// grow its KV by the chunk (plus the first-output-token slot on the
    /// final chunk), evicting offline residents if the allocator is short.
    /// Returns `None` (cursor stalls one iteration) when no room remains.
    fn schedule_chunk(
        &mut self,
        inst: usize,
        rid: RequestId,
        room: usize,
    ) -> Option<PrefillSegment> {
        let rem = self.cluster.requests[rid as usize].remaining_prefill();
        if rem == 0 || room == 0 {
            return None;
        }
        let take = rem.min(room);
        let last = take == rem;
        let grow = take + usize::from(last);
        if !self.fit_for_grow(inst, grow, rid) {
            return None;
        }
        self.cluster.relaxed[inst]
            .kv
            .grow(rid, grow)
            .expect("fit checked");
        Some(PrefillSegment {
            req: rid,
            tokens: take,
            last,
        })
    }

    /// Make room for a mid-prefill cursor's `tokens`-token growth,
    /// evicting offline work — but never `rid` itself (the request being
    /// grown). When `rid` is online and no offline work remains, another
    /// *online* mid-prefill resident is requeued instead: the conservative
    /// admission gate checks the full footprint but allocates
    /// incrementally, so concurrent online prefills can overcommit KV —
    /// without this last resort they would all stall forever (online
    /// residents are otherwise never evictable). The loser returns to the
    /// head of the online queue and re-admits once the winner finishes.
    /// Returns false when the cursor must stall an iteration.
    fn fit_for_grow(
        &mut self,
        inst: usize,
        tokens: usize,
        rid: RequestId,
    ) -> bool {
        while !self.cluster.relaxed[inst].kv.can_fit(tokens) {
            if let Some(&victim) =
                self.cluster.relaxed[inst].offline_decoding.first()
            {
                self.evict_offline_from_relaxed(inst, victim);
            } else if let Some(&victim) =
                self.cluster.relaxed[inst].inbound.first()
            {
                self.cancel_inbound_relaxed(inst, victim);
            } else {
                // Newest offline partial chain first (least recompute
                // wasted).
                let victim = self.cluster.relaxed[inst]
                    .prefilling
                    .iter()
                    .copied()
                    .rev()
                    .find(|&r| r != rid && !self.scheduled_online(r));
                if let Some(v) = victim {
                    self.evict_prefilling(inst, v);
                    continue;
                }
                if !self.scheduled_online(rid) {
                    return false;
                }
                // Online-vs-online overcommit: requeue the newest online
                // resident admitted *after* `rid` (oldest admission wins —
                // FIFO-fair and deadlock-free: the oldest resident can
                // always reclaim what later admissions overcommitted,
                // while a newer grower stalls instead of undoing older
                // work).
                let other = {
                    let pf = &self.cluster.relaxed[inst].prefilling;
                    let my_pos =
                        pf.iter().position(|&r| r == rid).unwrap_or(0);
                    pf[my_pos + 1..]
                        .iter()
                        .copied()
                        .rev()
                        .find(|&r| self.scheduled_online(r))
                };
                match other {
                    Some(v) => self.requeue_prefilling_online(inst, v),
                    None => return false,
                }
            }
        }
        true
    }

    /// Return an online mid-prefill resident to the head of its online
    /// queue (KV released, cursor reset — recompute on re-admission).
    /// Only used to break online-vs-online KV overcommit in
    /// [`SchedulerCore::fit_for_grow`].
    fn requeue_prefilling_online(&mut self, inst: usize, rid: RequestId) {
        self.cluster.relaxed[inst].kv.release(rid).expect("resident kv");
        self.cluster.relaxed[inst].prefilling.retain(|&r| r != rid);
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.requests[rid as usize].evict();
        self.cluster.relaxed[inst].online_queue.push_front(rid);
        self.cluster.evictions += 1;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Relaxed(inst),
            req: rid,
        });
    }

    /// Admit the head online request with its first chunk. The admission
    /// gate is conservative — the *full* remaining footprint must fit now
    /// (evicting offline work if needed) — but blocks are allocated
    /// incrementally per chunk.
    fn admit_chunked_online(
        &mut self,
        inst: usize,
        rid: RequestId,
        room: usize,
    ) -> AdmitChunk {
        let target = self.cluster.requests[rid as usize].recompute_len();
        let m = self.peek_prefix(InstanceRef::Relaxed(inst), rid);
        if !self.fit_on_relaxed(inst, target + 1, &m) {
            // Space held by other *online* requests frees on its own —
            // mid-prefill residents finish and dispatch, and a completed
            // prefill parked in a strict `waiting_for_space` queue still
            // holds its KV here until the dispatch retries. Wait instead
            // of dropping (in particular, an overcommit loser requeued by
            // `fit_for_grow` must survive until the winner leaves).
            let online_kv_resident = {
                let node = &self.cluster.relaxed[inst];
                node.kv
                    .resident_requests()
                    .any(|r| r != rid && self.scheduled_online(r))
            };
            if online_kv_resident {
                return AdmitChunk::NoSpace;
            }
            return AdmitChunk::Rejected;
        }
        AdmitChunk::Scheduled(
            self.admit_first_chunk(inst, rid, target, &m, room),
            m.cached_tokens,
        )
    }

    /// Admit the head offline request with its first chunk: space check
    /// keeps the online-prefill reserve intact and the §3.4.2 gating cost
    /// model prices the *remaining uncached* tokens it would compute.
    fn admit_chunked_offline(
        &mut self,
        inst: usize,
        rid: RequestId,
        room: usize,
    ) -> AdmitChunk {
        let target = self.cluster.requests[rid as usize].recompute_len();
        let m = self.peek_prefix(InstanceRef::Relaxed(inst), rid);
        let uncached = target.saturating_sub(m.cached_tokens).max(1);
        let free = self.cluster.relaxed[inst].kv.free_tokens();
        if free < target + 1 + ONLINE_PREFILL_RESERVE_TOKENS {
            return AdmitChunk::NoSpace;
        }
        let gating_on =
            self.cfg.policy.gating_enabled() && self.cfg.ablation.gating;
        if gating_on
            && !self.gating_admits(
                inst,
                rid,
                uncached,
                free - ONLINE_PREFILL_RESERVE_TOKENS,
            )
        {
            return AdmitChunk::NoSpace;
        }
        AdmitChunk::Scheduled(
            self.admit_first_chunk(inst, rid, target, &m, room),
            m.cached_tokens,
        )
    }

    /// Shared tail of chunked admission: open the cursor, reserve the
    /// cached blocks plus the first chunk, and join the `prefilling`
    /// residents. Fit was checked by the caller.
    fn admit_first_chunk(
        &mut self,
        inst: usize,
        rid: RequestId,
        target: usize,
        m: &PrefixMatch,
        room: usize,
    ) -> PrefillSegment {
        let uncached = target.saturating_sub(m.cached_tokens).max(1);
        let take = uncached.min(room.max(1));
        let last = take == uncached;
        let credit = m.cached_tokens.min(target.saturating_sub(1));
        let admit_tokens = credit + take + usize::from(last);
        self.admit_prefixed(InstanceRef::Relaxed(inst), rid, admit_tokens, m);
        self.note_prefix_use(InstanceRef::Relaxed(inst), rid, m, target);
        let req = &mut self.cluster.requests[rid as usize];
        req.phase = Phase::Prefilling;
        req.begin_prefill(target, m.cached_tokens);
        self.cluster.relaxed[inst].prefilling.push(rid);
        PrefillSegment {
            req: rid,
            tokens: take,
            last,
        }
    }

    /// Evict an offline mid-prefill resident for capacity: partial chains
    /// are not rescuable (the KV is incomplete), so this is always
    /// discard-and-recompute — the cursor resets with the eviction.
    fn evict_prefilling(&mut self, inst: usize, rid: RequestId) {
        self.cluster.relaxed[inst].kv.release(rid).expect("resident kv");
        self.cluster.relaxed[inst].prefilling.retain(|&r| r != rid);
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.requests[rid as usize].evict();
        self.cluster.offline_backlog.push_back(rid);
        self.cluster.evictions += 1;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Relaxed(inst),
            req: rid,
        });
    }

    /// Batch online prefills up to the token budget. Declared shared
    /// prefixes resolve against the instance's cache first: cached tokens
    /// are admitted as block references and priced at zero — the budget,
    /// the roofline cost, and the emitted `StartStep` all see only the
    /// uncached remainder (§3.7).
    fn start_online_prefill(&mut self, inst: usize) -> bool {
        if self.cluster.relaxed[inst].online_queue.is_empty() {
            return false;
        }
        let budget = self.cfg.serving.sched.prefill_token_budget;
        let mut batch: Vec<RequestId> = Vec::new();
        let mut lens: Vec<usize> = Vec::new();
        let mut used = 0usize;
        let mut cached_total = 0usize;
        while let Some(&rid) = self.cluster.relaxed[inst].online_queue.front() {
            let len = self.cluster.requests[rid as usize].recompute_len();
            let m = self.peek_prefix(InstanceRef::Relaxed(inst), rid);
            // A fully cached prompt still runs one query token to produce
            // its first output token.
            let uncached = len.saturating_sub(m.cached_tokens).max(1);
            if !batch.is_empty() && used + uncached > budget {
                break;
            }
            // KV space for the prefill output, evicting offline if needed.
            if !self.fit_on_relaxed(inst, len + 1, &m) {
                if batch.is_empty() {
                    // Head request cannot fit even after eviction: reject.
                    self.cluster.relaxed[inst].online_queue.pop_front();
                    self.cluster.requests[rid as usize].phase = Phase::Finished;
                    self.actions.push(Action::Complete { req: rid });
                    continue;
                }
                break;
            }
            self.cluster.relaxed[inst].online_queue.pop_front();
            self.admit_prefixed(InstanceRef::Relaxed(inst), rid, len + 1, &m);
            self.note_prefix_use(InstanceRef::Relaxed(inst), rid, &m, len);
            let req = &mut self.cluster.requests[rid as usize];
            req.phase = Phase::Prefilling;
            req.begin_prefill(len, m.cached_tokens);
            used += uncached;
            cached_total += m.cached_tokens;
            batch.push(rid);
            lens.push(uncached);
        }
        if batch.is_empty() {
            return false;
        }
        let latency = self.pm.prefill_cost(&lens).latency_s;
        self.begin_relaxed_step(
            inst,
            StepKind::PrefillOnline,
            batch,
            latency,
            cached_total,
        );
        true
    }

    /// Make room for `tokens` on a relaxed instance by evicting offline
    /// decode residents (oldest first — relaxed nodes have no bottleneck
    /// preference; their decode batch has no SLO), then — if still short —
    /// by cancelling in-flight rescue/restore reservations. `m` is the
    /// admission's prefix match: shared blocks reduce the private need but
    /// cannot double as free capacity (the admission pins them). Evicted
    /// residents release their blocks to the cache, not to oblivion, so
    /// the match stays valid across the loop.
    fn fit_on_relaxed(
        &mut self,
        inst: usize,
        tokens: usize,
        m: &PrefixMatch,
    ) -> bool {
        while !self.cluster.relaxed[inst]
            .kv
            .can_admit_shared(tokens, &m.full_blocks)
        {
            // Evict a parked/decoding offline resident not in the current
            // step (relaxed instance is idle here, so all are safe).
            if let Some(&victim) =
                self.cluster.relaxed[inst].offline_decoding.first()
            {
                self.evict_offline_from_relaxed(inst, victim);
            } else if let Some(&victim) =
                self.cluster.relaxed[inst].inbound.first()
            {
                self.cancel_inbound_relaxed(inst, victim);
            } else {
                // Chunked mode: an offline mid-prefill resident's partial
                // chain makes way (discard-and-recompute; never online).
                // Newest first — the least-progressed chain wastes the
                // least recompute.
                let victim = self.cluster.relaxed[inst]
                    .prefilling
                    .iter()
                    .copied()
                    .rev()
                    .find(|&r| !self.scheduled_online(r));
                match victim {
                    Some(v) => self.evict_prefilling(inst, v),
                    None => return false,
                }
            }
        }
        true
    }

    fn evict_offline_from_relaxed(&mut self, inst: usize, rid: RequestId) {
        self.cluster.relaxed[inst].kv.release(rid).expect("resident kv");
        self.cluster.relaxed[inst]
            .offline_decoding
            .retain(|&r| r != rid);
        // Recoverable fast preemption: park the KV in host staging instead
        // of discarding it (no second relaxed home for it here — the online
        // prefill claiming this space may need the whole pool).
        if self.cfg.policy.offline_decode_on_relaxed()
            && self.transport.recoverable_eviction
            && self.transport.host_staging
        {
            self.cluster.kv_home[rid as usize] = KvHome::Staged;
            self.cluster.requests[rid as usize].phase = Phase::Migrating;
            self.cluster.evict_started[rid as usize] = self.now;
            self.cluster.offloads += 1;
            let kv_len = self.cluster.requests[rid as usize].kv_len();
            self.enqueue_transfer(rid, TransferKind::Offload, kv_len);
            return;
        }
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.requests[rid as usize].evict();
        self.cluster.offline_backlog.push_back(rid);
        self.cluster.evictions += 1;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Relaxed(inst),
            req: rid,
        });
    }

    /// Abort an in-flight rescue/restore whose reserved KV the online path
    /// needs. The transport releases the job's resources exactly once
    /// (property-tested); the request falls back to discard-and-recompute.
    fn cancel_inbound_relaxed(&mut self, inst: usize, rid: RequestId) {
        let job = self
            .transport
            .job_of(rid)
            .expect("inbound request has an active job");
        let cancelled =
            self.transport.cancel(job).expect("first cancel of active job");
        self.actions.push(Action::TransferCancel {
            job: cancelled.id,
            req: rid,
        });
        self.cluster.relaxed[inst].kv.release(rid).expect("reserved kv");
        self.cluster.relaxed[inst].inbound.retain(|&r| r != rid);
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.evict_started[rid as usize] = f64::NAN;
        self.cluster.requests[rid as usize].evict();
        self.cluster.offline_backlog.push_back(rid);
        self.cluster.evictions += 1;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Relaxed(inst),
            req: rid,
        });
    }

    /// Admit offline prefills from the global backlog (gating in OOCO,
    /// plain idle-only admission in `online priority`).
    fn start_offline_prefill(&mut self, inst: usize) -> bool {
        if self.cluster.offline_backlog.is_empty()
            || !self.cluster.relaxed[inst].accepts_work()
        {
            return false;
        }
        // base P/D never reaches here (offline went through the online path).
        let budget = self.cfg.serving.sched.prefill_token_budget;
        let gating_on =
            self.cfg.policy.gating_enabled() && self.cfg.ablation.gating;
        let mut batch = Vec::new();
        let mut lens = Vec::new();
        let mut used = 0usize;
        let mut cached_total = 0usize;
        let reserve = ONLINE_PREFILL_RESERVE_TOKENS;
        while let Some(&rid) = self.cluster.offline_backlog.front() {
            let len = self.cluster.requests[rid as usize].recompute_len();
            let m = self.peek_prefix(InstanceRef::Relaxed(inst), rid);
            let uncached = len.saturating_sub(m.cached_tokens).max(1);
            if !batch.is_empty() && used + uncached > budget {
                break;
            }
            // Space check stays on the full length (conservative: shared
            // blocks reduce the private need, never increase it), keeping
            // the online-prefill reserve intact.
            let free = self.cluster.relaxed[inst].kv.free_tokens();
            if free < len + 1 + reserve {
                break;
            }
            // The gating cost model prices the prefill it would actually
            // run: the uncached remainder.
            if gating_on
                && !self.gating_admits(inst, rid, uncached, free - reserve)
            {
                break;
            }
            self.cluster.offline_backlog.pop_front();
            self.admit_prefixed(InstanceRef::Relaxed(inst), rid, len + 1, &m);
            self.note_prefix_use(InstanceRef::Relaxed(inst), rid, &m, len);
            let req = &mut self.cluster.requests[rid as usize];
            req.phase = Phase::Prefilling;
            req.begin_prefill(len, m.cached_tokens);
            used += uncached;
            cached_total += m.cached_tokens;
            batch.push(rid);
            lens.push(uncached);
            self.actions.push(Action::Admit { inst, req: rid });
        }
        if batch.is_empty() {
            return false;
        }
        let latency = self.pm.prefill_cost(&lens).latency_s;
        self.begin_relaxed_step(
            inst,
            StepKind::PrefillOffline,
            batch,
            latency,
            cached_total,
        );
        true
    }

    fn gating_admits(
        &mut self,
        inst: usize,
        rid: RequestId,
        prefill_tokens: usize,
        free: usize,
    ) -> bool {
        let pool = self.relaxed_pool_stats(inst);
        let req = &self.cluster.requests[rid as usize];
        let remaining: f64 = if self.cluster.relaxed[inst]
            .offline_decoding
            .is_empty()
        {
            0.0
        } else {
            self.cluster.relaxed[inst]
                .offline_decoding
                .iter()
                .map(|&r| {
                    let q = &self.cluster.requests[r as usize];
                    (q.output_len - q.generated.min(q.output_len)) as f64
                })
                .sum::<f64>()
                / self.cluster.relaxed[inst].offline_decoding.len() as f64
        };
        let input = crate::coordinator::GatingInput {
            pool,
            candidate_prompt: prefill_tokens,
            candidate_output: req.output_len,
            pool_mean_remaining: remaining,
            free_kv_tokens: free,
        };
        crate::coordinator::should_prefill_offline(
            &self.pm,
            &input,
            &self.cfg.serving.sched,
        )
    }

    fn relaxed_pool_stats(&self, inst: usize) -> BatchStats {
        let mut s = BatchStats::empty();
        for &r in &self.cluster.relaxed[inst].offline_decoding {
            s = s.with(self.cluster.requests[r as usize].kv_len());
        }
        s
    }

    /// Offline decode on a relaxed instance (OOCO's latency-constraint
    /// flexibility): batch every resident — no per-iteration bound here.
    fn start_relaxed_decode(&mut self, inst: usize) {
        if !self.cfg.policy.offline_decode_on_relaxed()
            || self.cluster.relaxed[inst].offline_decoding.is_empty()
            // A draining or evacuating instance starts no new decode
            // steps: its residents are being streamed off, and an idle
            // instance is what lets the next tick evict the stragglers.
            || !self.cluster.relaxed[inst].accepts_work()
        {
            return;
        }
        let batch: Vec<RequestId> =
            self.cluster.relaxed[inst].offline_decoding.clone();
        let stats = self.relaxed_pool_stats(inst);
        let latency = self.pm.decode_latency(stats);
        self.begin_relaxed_step(inst, StepKind::DecodeRelaxed, batch, latency, 0);
    }

    fn begin_relaxed_step(
        &mut self,
        inst: usize,
        kind: StepKind,
        participants: Vec<RequestId>,
        latency: f64,
        cached_tokens: usize,
    ) {
        self.begin_relaxed_step_composed(
            inst,
            kind,
            participants,
            Vec::new(),
            latency,
            cached_tokens,
        );
    }

    /// Shared step-creation tail for every relaxed iteration — exclusive
    /// (`prefill` empty) and composed alike: one place owns the seq
    /// allocation, span clamp, action emission, and busy accrual.
    fn begin_relaxed_step_composed(
        &mut self,
        inst: usize,
        kind: StepKind,
        participants: Vec<RequestId>,
        prefill: Vec<PrefillSegment>,
        latency: f64,
        cached_tokens: usize,
    ) {
        let seq = self.cluster.alloc_seq();
        let span = latency.max(1e-9);
        let ends = self.now + span;
        // Pooled copies for the action stream (value-identical to clones;
        // the executor recycles them back after dispatch).
        let mut action_ids = self.pooled_ids();
        action_ids.extend_from_slice(&participants);
        let mut action_segs = self.pooled_segs();
        action_segs.extend_from_slice(&prefill);
        self.actions.push(Action::StartStep {
            inst: InstanceRef::Relaxed(inst),
            kind,
            participants: action_ids,
            prefill: action_segs,
            predicted_latency: span,
            cached_tokens,
            seq,
        });
        self.cluster.relaxed[inst].step = Some(Step {
            kind,
            started: self.now,
            ends,
            participants,
            prefill,
            seq,
            preempted: false,
        });
        self.cluster.relaxed[inst].busy_s += latency;
    }

    fn relaxed_step_end(&mut self, inst: usize, seq: u64) {
        // `.get`: a stale (preemption-superseded) event can name a tail
        // index an elastic flip has since vacated — treat it exactly like
        // a superseded seq. Cluster-global seq uniqueness guarantees a
        // stale event can never alias a different instance's live step
        // after a later flip refills the index.
        let valid = self
            .cluster
            .relaxed
            .get(inst)
            .and_then(|r| r.step.as_ref())
            .map(|s| s.seq == seq)
            .unwrap_or(false);
        if !valid {
            return; // stale completion after preemption reschedule or flip
        }
        let step = self.cluster.relaxed[inst].step.take().expect("checked");
        match step.kind {
            StepKind::PrefillOnline => {
                for &rid in &step.participants {
                    self.complete_prefill_cursor(rid);
                    self.finish_prefill_online(inst, rid);
                }
            }
            StepKind::PrefillOffline => {
                if step.preempted {
                    // Layer-level interruption: work discarded, requests
                    // return to the backlog for recompute (exclusive-step
                    // mode only — the chunked model retains progress).
                    // (The discarded-work tokens were booked at the
                    // truncation decision in `maybe_preempt`, where the
                    // elapsed fraction was known.)
                    for &rid in &step.participants {
                        self.cluster.relaxed[inst].kv.release(rid).expect("kv");
                        self.cluster.kv_home[rid as usize] = KvHome::None;
                        let req = &mut self.cluster.requests[rid as usize];
                        req.prefilled_tokens = 0;
                        req.prefill_target = 0;
                        req.prefill_cached = 0;
                        req.phase = Phase::Queued;
                        self.cluster.offline_backlog.push_front(rid);
                    }
                } else {
                    for &rid in &step.participants {
                        self.complete_prefill_cursor(rid);
                        self.finish_prefill_offline(inst, rid);
                    }
                }
            }
            StepKind::DecodeRelaxed => {
                for &rid in &step.participants {
                    self.relaxed_decode_token(inst, rid);
                }
            }
            StepKind::Composed => {
                // Decode side first (token marks may free space), then the
                // prefill cursors advance by their scheduled segments.
                for &rid in &step.participants {
                    self.relaxed_decode_token(inst, rid);
                }
                for seg in &step.prefill {
                    let rid = seg.req;
                    // Evicted/migrated-mid-step guard, as in decode.
                    if self.cluster.kv_home[rid as usize]
                        != KvHome::Relaxed(inst)
                        || self.cluster.requests[rid as usize].phase
                            != Phase::Prefilling
                    {
                        continue;
                    }
                    self.cluster.requests[rid as usize]
                        .advance_prefill(seg.tokens);
                    if seg.last {
                        self.cluster.relaxed[inst]
                            .prefilling
                            .retain(|&r| r != rid);
                        if self.scheduled_online(rid) {
                            self.finish_prefill_online(inst, rid);
                        } else {
                            self.finish_prefill_offline(inst, rid);
                        }
                    }
                }
            }
            StepKind::Warm => {
                // Role-transition warm-up finished (strict→relaxed flip):
                // the instance joins the relaxed pool for real.
                self.complete_warm(InstanceRef::Relaxed(inst));
            }
            StepKind::DecodeStrict => unreachable!("strict step on relaxed"),
        }
        self.recycle_step(step);
        self.start_relaxed_step(inst);
    }

    /// Exclusive-step completion: the whole uncached remainder ran in one
    /// step — advance the cursor to the target so both iteration models
    /// share one completion invariant (checked in `finish_prefill_*`).
    fn complete_prefill_cursor(&mut self, rid: RequestId) {
        let req = &mut self.cluster.requests[rid as usize];
        let rem = req.remaining_prefill();
        req.advance_prefill(rem);
    }

    /// The §3.8 conservation check, run at every prefill completion: the
    /// cursor must land exactly on the admission-time target — a mismatch
    /// means a chunk was lost or double-counted across
    /// preemption/eviction/migration (property-tested to stay 0).
    fn audit_prefill_cursor(&mut self, rid: RequestId) {
        let req = &self.cluster.requests[rid as usize];
        if req.prefill_target == 0
            || req.prefilled_tokens != req.prefill_target
        {
            self.cluster.chunk_accounting_errors += 1;
        }
    }

    fn finish_prefill_online(&mut self, inst: usize, rid: RequestId) {
        self.audit_prefill_cursor(rid);
        let recompute = self.cluster.requests[rid as usize].recompute_len();
        self.cluster.router.prefill_done(inst, recompute);
        // The freshly computed prefix chain becomes cache content *before*
        // any release/dispatch below — released blocks then retain as
        // reclaimable cache instead of freeing.
        self.register_prefix(InstanceRef::Relaxed(inst), rid);
        self.cluster.requests[rid as usize].mark_first_token(self.now);
        if self.cluster.requests[rid as usize].is_finished() {
            // Single-token request: done at prefill.
            self.cluster.requests[rid as usize].finished_at = Some(self.now);
            self.cluster.requests[rid as usize].phase = Phase::Finished;
            self.cluster.relaxed[inst].kv.release(rid).expect("kv");
            self.cluster.kv_home[rid as usize] = KvHome::None;
            self.actions.push(Action::Complete { req: rid });
            return;
        }
        // Push model: dispatch to a strict instance immediately.
        let kv_len = self.cluster.requests[rid as usize].kv_len();
        let target = self.cluster.router.route_decode(kv_len);
        self.try_dispatch_to_strict(rid, inst, target);
    }

    /// Reserve KV on the strict instance (evicting offline per policy) and
    /// start the transfer; park in `waiting_for_space` on failure. Prefix
    /// blocks already resident on the target are referenced, not moved.
    fn try_dispatch_to_strict(
        &mut self,
        rid: RequestId,
        from_relaxed: usize,
        target: usize,
    ) {
        let kv_len = self.cluster.requests[rid as usize].kv_len();
        let need = kv_len + 1;
        if !self.cluster.strict[target].kv.can_fit(need) {
            self.make_room_on_strict(target, need);
        }
        if self.cluster.strict[target].kv.can_fit(need) {
            let m = self.peek_prefix(InstanceRef::Strict(target), rid);
            self.admit_prefixed(InstanceRef::Strict(target), rid, need, &m);
            self.cluster.relaxed[from_relaxed].kv.release(rid).expect("kv");
            self.cluster.requests[rid as usize].phase = Phase::Migrating;
            self.cluster.strict[target].inbound.push(rid);
            let moved = self.transfer_tokens_for(rid, &m);
            self.enqueue_transfer(
                rid,
                TransferKind::Dispatch { to_strict: target },
                moved,
            );
        } else {
            // Overload: wait (KV stays on the relaxed node).
            self.cluster.strict[target].waiting_for_space.push_back(rid);
        }
    }

    /// Evict offline decode residents on a strict instance to free `need`
    /// tokens. Only legal between steps; callers run at step boundaries.
    fn make_room_on_strict(&mut self, inst: usize, need: usize) {
        if self.cluster.strict[inst].offline.is_empty() {
            return;
        }
        // Victim candidates into the reusable scratch (hot path: runs on
        // decode-growth overflow); running-step membership is checked in
        // place instead of cloning the participant list.
        let mut victims = std::mem::take(&mut self.scratch_offline);
        victims.clear();
        {
            let node = &self.cluster.strict[inst];
            let step = node.step.as_ref();
            victims.extend(
                node.offline
                    .iter()
                    .filter(|&&r| step.map(|s| !s.involves(r)).unwrap_or(true))
                    .map(|&r| {
                        (r, self.cluster.requests[r as usize].kv_len())
                    }),
            );
        }
        if victims.is_empty() {
            self.scratch_offline = victims;
            return;
        }
        let free_now = self.cluster.strict[inst].kv.free_tokens();
        let deficit = need.saturating_sub(free_now);
        if deficit == 0 {
            self.scratch_offline = victims;
            return;
        }
        let stats = self.strict_resident_stats(inst);
        let bottleneck = self.pm.decode_bottleneck(stats);
        let aware = self.cfg.policy.bottleneck_aware_eviction()
            && self.cfg.ablation.bottleneck_eviction;
        let chosen =
            select_evictions(&self.pm, &victims, deficit, bottleneck, aware);
        self.scratch_offline = victims;
        for rid in chosen {
            self.evict_offline_from_strict(inst, rid);
        }
    }

    fn evict_offline_from_strict(&mut self, inst: usize, rid: RequestId) {
        let kv = self.cluster.requests[rid as usize].kv_len();
        self.cluster.strict[inst].kv.release(rid).expect("resident");
        self.cluster.strict[inst].remove_offline(rid);
        self.cluster.router.decode_done(inst, kv);
        if self.try_rescue(rid) {
            return;
        }
        self.cluster.kv_home[rid as usize] = KvHome::None;
        self.cluster.requests[rid as usize].evict();
        self.cluster.offline_backlog.push_back(rid);
        self.cluster.evictions += 1;
        self.actions.push(Action::Evict {
            inst: InstanceRef::Strict(inst),
            req: rid,
        });
        self.kick_idle_relaxed();
    }

    /// §3.4.1 recoverable fast preemption: the strict node is freed the
    /// moment the caller released `rid`'s blocks; instead of discarding the
    /// KV for full recompute, stream it into the relaxed pool (preferred)
    /// or the host staging buffer. Returns false when recovery is off or
    /// nowhere can take the bytes — the caller falls back to
    /// discard-and-recompute.
    fn try_rescue(&mut self, rid: RequestId) -> bool {
        if !(self.cfg.policy.offline_decode_on_relaxed()
            && self.transport.recoverable_eviction)
        {
            return false;
        }
        let need = self.cluster.requests[rid as usize].kv_len() + 1;
        // Keep the online-prefill headroom at the destination: a rescue
        // that fills the pool to the brim would just be cancelled by the
        // next online prefill and discarded after burning link bandwidth.
        let dest = (0..self.cluster.relaxed.len())
            .filter(|&i| {
                self.cluster.relaxed[i].accepts_work()
                    && self.cluster.relaxed[i].kv.free_tokens()
                        >= need + ONLINE_PREFILL_RESERVE_TOKENS
            })
            .max_by_key(|&i| self.cluster.relaxed[i].kv.free_tokens());
        if let Some(i) = dest {
            let m = self.peek_prefix(InstanceRef::Relaxed(i), rid);
            self.admit_prefixed(InstanceRef::Relaxed(i), rid, need, &m);
            self.cluster.requests[rid as usize].phase = Phase::Migrating;
            self.cluster.relaxed[i].inbound.push(rid);
            self.cluster.evict_started[rid as usize] = self.now;
            self.cluster.rescues += 1;
            let moved = self.transfer_tokens_for(rid, &m);
            self.enqueue_transfer(
                rid,
                TransferKind::Rescue { to_relaxed: i },
                moved,
            );
            return true;
        }
        if self.transport.host_staging {
            self.cluster.kv_home[rid as usize] = KvHome::Staged;
            self.cluster.requests[rid as usize].phase = Phase::Migrating;
            self.cluster.evict_started[rid as usize] = self.now;
            self.cluster.offloads += 1;
            let kv_len = self.cluster.requests[rid as usize].kv_len();
            self.enqueue_transfer(rid, TransferKind::Offload, kv_len);
            return true;
        }
        false
    }

    fn finish_prefill_offline(&mut self, inst: usize, rid: RequestId) {
        self.audit_prefill_cursor(rid);
        self.register_prefix(InstanceRef::Relaxed(inst), rid);
        self.cluster.requests[rid as usize].mark_first_token(self.now);
        if self.cluster.requests[rid as usize].is_finished() {
            self.cluster.requests[rid as usize].finished_at = Some(self.now);
            self.cluster.requests[rid as usize].phase = Phase::Finished;
            self.cluster.relaxed[inst].kv.release(rid).expect("kv");
            self.cluster.kv_home[rid as usize] = KvHome::None;
            self.actions.push(Action::Complete { req: rid });
            return;
        }
        if self.cfg.policy.offline_decode_on_relaxed() {
            // OOCO: decode right here; the strict pool pulls later (Alg. 1).
            self.cluster.requests[rid as usize].phase = Phase::Decoding;
            self.cluster.relaxed[inst].offline_decoding.push(rid);
        } else {
            // online priority: offline decode belongs to the strict pool.
            let kv_len = self.cluster.requests[rid as usize].kv_len();
            let target = self.cluster.router.route_decode(kv_len);
            if self.cluster.strict[target].kv.can_fit(kv_len + 1) {
                let m = self.peek_prefix(InstanceRef::Strict(target), rid);
                self.admit_prefixed(
                    InstanceRef::Strict(target),
                    rid,
                    kv_len + 1,
                    &m,
                );
                self.cluster.relaxed[inst].kv.release(rid).expect("kv");
                self.cluster.requests[rid as usize].phase = Phase::Migrating;
                self.cluster.strict[target].inbound.push(rid);
                let moved = self.transfer_tokens_for(rid, &m);
                self.enqueue_transfer(
                    rid,
                    TransferKind::Dispatch { to_strict: target },
                    moved,
                );
            } else {
                // Park on the relaxed node (holds KV, does not decode);
                // retried at strict step boundaries.
                self.cluster.router.decode_done(target, kv_len);
                self.cluster.relaxed[inst].offline_decoding.push(rid);
            }
        }
    }

    fn relaxed_decode_token(&mut self, inst: usize, rid: RequestId) {
        // Evicted/migrated-mid-step guard, O(1) via the location index
        // (migration moves kv_home to Strict; eviction resets it to None).
        // The phase check additionally skips requests whose KV is being
        // rescued *back* onto this instance mid-step (kv_home already
        // points here but the stream has not landed: phase is Migrating).
        if self.cluster.kv_home[rid as usize] != KvHome::Relaxed(inst)
            || self.cluster.requests[rid as usize].phase != Phase::Decoding
        {
            return;
        }
        let done = self.cluster.requests[rid as usize].mark_token(self.now);
        if done {
            self.cluster.relaxed[inst].kv.release(rid).expect("kv");
            self.cluster.relaxed[inst]
                .offline_decoding
                .retain(|&r| r != rid);
            self.cluster.kv_home[rid as usize] = KvHome::None;
            self.actions.push(Action::Complete { req: rid });
            return;
        }
        if self.cluster.relaxed[inst].kv.grow(rid, 1).is_err() {
            self.evict_offline_from_relaxed(inst, rid);
        }
    }

    // ------------------------------------------------------ strict stepping

    fn strict_resident_stats(&self, inst: usize) -> BatchStats {
        let mut s = BatchStats::empty();
        for &r in self.cluster.strict[inst]
            .online
            .iter()
            .chain(&self.cluster.strict[inst].offline)
        {
            s = s.with(self.cluster.requests[r as usize].kv_len());
        }
        s
    }

    fn start_strict_step(&mut self, inst: usize) {
        if self.cluster.strict[inst].down
            || !self.cluster.strict[inst].is_idle()
            || !self.cluster.strict[inst].has_decode_work()
        {
            return;
        }
        // Participant candidates into the reusable scratch buffers (hot
        // path: every strict iteration rebuilds these).
        let mut online = std::mem::take(&mut self.scratch_online);
        online.clear();
        online.extend(
            self.cluster.strict[inst]
                .online
                .iter()
                .map(|&r| (r, self.cluster.requests[r as usize].kv_len())),
        );

        // §3.4.4 overload handling: in Shed mode, sacrifice the longest
        // online requests when even the online-only batch exceeds the SLO,
        // preserving the SLO for the remainder (OOCO only — baselines have
        // no latency predictor to act on).
        if self.cfg.overload_mode == OverloadMode::Shed
            && self.cfg.policy == Policy::Ooco
            && !online.is_empty()
        {
            let toks: usize = online.iter().map(|c| c.1).sum();
            let stats = BatchStats::new(online.len(), toks);
            if self.pm.decode_latency(stats) > self.cfg.serving.slo.tpot {
                let (kept, shed) = shed_online_overload(
                    &self.pm,
                    &online,
                    self.cfg.serving.slo.tpot,
                );
                for rid in shed {
                    let kv = self.cluster.requests[rid as usize].kv_len();
                    self.cluster.strict[inst].kv.release(rid).expect("resident");
                    self.cluster.strict[inst].remove_online(rid);
                    self.cluster.router.decode_done(inst, kv);
                    self.cluster.kv_home[rid as usize] = KvHome::None;
                    // Sacrificed: terminal, unfinished -> counts as an SLO
                    // violation in the report (the paper's trade).
                    self.cluster.requests[rid as usize].phase = Phase::Finished;
                    self.actions.push(Action::Complete { req: rid });
                }
                online = kept;
            }
        }
        // A draining or evacuating strict instance batches online
        // residents only: its offline mix-ins must sit out the step so
        // the sweep ticks can stream them off between iterations.
        let mut offline = std::mem::take(&mut self.scratch_offline);
        offline.clear();
        if self.cluster.strict[inst].accepts_work() {
            offline.extend(
                self.cluster.strict[inst]
                    .offline
                    .iter()
                    .map(|&r| (r, self.cluster.requests[r as usize].kv_len())),
            );
        }

        let slo = self.cfg.serving.slo.tpot;
        let selection = match self.cfg.policy {
            Policy::Ooco if self.cfg.ablation.mix_decode => select_decode_batch(
                &self.pm,
                &online,
                &offline,
                slo,
                self.cfg.serving.sched.mix_probe_iters,
                &mut self.rng,
            ),
            Policy::Ooco => select_decode_batch_capped(
                &online,
                &offline,
                self.cfg.serving.sched.baseline_decode_cap,
            ),
            Policy::OnlinePriority => select_decode_batch_capped(
                &online,
                &offline,
                self.cfg.serving.sched.baseline_decode_cap,
            ),
            Policy::BasePd => {
                // Everything is "online": batch all residents, no bound.
                select_decode_batch_capped(&online, &offline, usize::MAX)
            }
        };

        let mut participants = self.pooled_ids();
        participants.extend(online.iter().map(|c| c.0));
        participants.extend(&selection.offline);
        // Return the scratch buffers before any exit path.
        self.scratch_online = online;
        self.scratch_offline = offline;
        if participants.is_empty() {
            self.recycle_ids(participants);
            return;
        }
        let stats = selection.stats;
        let latency = self.pm.decode_latency(stats);
        let all_included = participants.len()
            == self.cluster.strict[inst].online.len()
                + self.cluster.strict[inst].offline.len();

        let seq = self.cluster.alloc_seq();
        let span = latency.max(1e-9);
        let ends = self.now + span;
        let mut action_ids = self.pooled_ids();
        action_ids.extend_from_slice(&participants);
        self.actions.push(Action::StartStep {
            inst: InstanceRef::Strict(inst),
            kind: StepKind::DecodeStrict,
            participants: action_ids,
            prefill: Vec::new(),
            predicted_latency: span,
            cached_tokens: 0,
            seq,
        });
        self.cluster.strict[inst].step = Some(Step {
            kind: StepKind::DecodeStrict,
            started: self.now,
            ends,
            participants,
            prefill: Vec::new(),
            seq,
            preempted: false,
        });
        self.cluster.strict[inst].busy_s += latency;
        self.cluster.strict[inst].steps += 1;
        // Stash per-step info for the migration decision at the boundary.
        self.cluster.strict_step_meta[inst] = Some((stats, all_included));
    }

    fn strict_step_end(&mut self, inst: usize, seq: u64) {
        // `.get` for the same stale-event-after-flip reason as
        // `relaxed_step_end`.
        let valid = self
            .cluster
            .strict
            .get(inst)
            .and_then(|s| s.step.as_ref())
            .map(|s| s.seq == seq)
            .unwrap_or(false);
        if !valid {
            return;
        }
        let step = self.cluster.strict[inst].step.take().expect("checked");
        if step.kind == StepKind::Warm {
            // Role-transition warm-up finished (relaxed→strict flip); fall
            // through to the ordinary boundary work so the fresh instance
            // starts serving immediately.
            self.complete_warm(InstanceRef::Strict(inst));
        }
        for &rid in &step.participants {
            self.strict_decode_token(inst, rid);
        }
        self.recycle_step(step);
        // Step boundary work: retry waiting admissions, then migration pull.
        self.retry_waiting(inst);
        self.maybe_pull_migration(inst);
        self.pull_parked_offline(inst);
        self.start_strict_step(inst);
    }

    fn strict_decode_token(&mut self, inst: usize, rid: RequestId) {
        let is_online = self.cluster.requests[rid as usize].class.is_online()
            || self.cfg.policy == Policy::BasePd;
        // Evicted-mid-step guard. PERF (§Perf): O(1) via the kv_home
        // location index — a `Vec::contains` residency check would be
        // O(batch) per participant, O(batch^2) per step.
        if self.cluster.kv_home[rid as usize] != KvHome::Strict(inst) {
            return;
        }
        if self.cluster.requests[rid as usize].class
            == crate::request::Class::Offline
        {
            self.cluster.strict[inst].offline_decode_tokens += 1;
        }
        let done = self.cluster.requests[rid as usize].mark_token(self.now);
        let kv = self.cluster.requests[rid as usize].kv_len();
        if done {
            self.cluster.strict[inst].kv.release(rid).expect("kv");
            if is_online {
                self.cluster.strict[inst].remove_online(rid);
            } else {
                self.cluster.strict[inst].remove_offline(rid);
            }
            self.cluster.router.decode_done(inst, kv);
            self.cluster.kv_home[rid as usize] = KvHome::None;
            self.actions.push(Action::Complete { req: rid });
            return;
        }
        self.cluster.router.decode_grow(inst, 1);
        if self.cluster.strict[inst].kv.grow(rid, 1).is_err() {
            if is_online {
                // Free offline space for the online request's growth.
                self.make_room_on_strict(inst, self.cfg.block_tokens);
                if self.cluster.strict[inst].kv.grow(rid, 1).is_err() {
                    // True overload; token produced, KV undercounted by one
                    // block until space frees (documented approximation).
                }
            } else {
                self.evict_offline_from_strict(inst, rid);
            }
        }
    }

    /// Retry online requests that were waiting for strict KV space.
    fn retry_waiting(&mut self, inst: usize) {
        let mut remaining = std::collections::VecDeque::new();
        while let Some(rid) =
            self.cluster.strict[inst].waiting_for_space.pop_front()
        {
            let kv_len = self.cluster.requests[rid as usize].kv_len();
            let need = kv_len + 1;
            if !self.cluster.strict[inst].kv.can_fit(need) {
                self.make_room_on_strict(inst, need);
            }
            if self.cluster.strict[inst].kv.can_fit(need) {
                let from = match self.cluster.kv_home[rid as usize] {
                    KvHome::Relaxed(i) => i,
                    _ => unreachable!("waiting request KV must be on relaxed"),
                };
                let m = self.peek_prefix(InstanceRef::Strict(inst), rid);
                self.admit_prefixed(InstanceRef::Strict(inst), rid, need, &m);
                self.cluster.relaxed[from].kv.release(rid).expect("kv");
                self.cluster.strict[inst].inbound.push(rid);
                let moved = self.transfer_tokens_for(rid, &m);
                self.enqueue_transfer(
                    rid,
                    TransferKind::Dispatch { to_strict: inst },
                    moved,
                );
            } else {
                remaining.push_back(rid);
            }
        }
        self.cluster.strict[inst].waiting_for_space = remaining;
    }

    /// Algorithm 1: pull offline decodes from relaxed nodes when headroom
    /// exists (OOCO only).
    fn maybe_pull_migration(&mut self, inst: usize) {
        if !self.cfg.policy.migration_enabled() || !self.cfg.ablation.migration
        {
            return;
        }
        if !self.cluster.strict[inst].accepts_work() {
            // A draining/evacuating instance pulls no new offline decodes.
            self.cluster.strict_step_meta[inst] = None;
            return;
        }
        let Some((stats, all_included)) =
            self.cluster.strict_step_meta[inst].take()
        else {
            return;
        };
        let pref = migration_decision(
            &self.pm,
            stats,
            all_included,
            self.cfg.serving.slo.tpot,
            self.cfg.serving.sched.slo_margin,
        );
        if pref == LengthPref::None {
            return;
        }
        // Pull from the relaxed instance with the largest offline pool.
        let Some(src) = (0..self.cluster.relaxed.len())
            .filter(|&i| !self.cluster.relaxed[i].offline_decoding.is_empty())
            .max_by_key(|&i| self.cluster.relaxed[i].offline_decoding.len())
        else {
            return;
        };
        let mut cands = std::mem::take(&mut self.scratch_offline);
        cands.clear();
        cands.extend(
            self.cluster.relaxed[src]
                .offline_decoding
                .iter()
                .map(|&r| (r, self.cluster.requests[r as usize].kv_len())),
        );
        let picked = pick_migration_candidates(
            pref,
            &cands,
            self.cfg.serving.sched.migration_batch,
        );
        self.scratch_offline = cands;
        for rid in picked {
            // Relaxed decode step may be running with this request; removal
            // from residency makes the in-flight token a no-op (guarded in
            // relaxed_decode_token).
            let kv_len = self.cluster.requests[rid as usize].kv_len();
            if !self.cluster.strict[inst].kv.can_fit(kv_len + 1) {
                break;
            }
            let m = self.peek_prefix(InstanceRef::Strict(inst), rid);
            self.admit_prefixed(InstanceRef::Strict(inst), rid, kv_len + 1, &m);
            self.cluster.relaxed[src].kv.release(rid).expect("kv");
            self.cluster.relaxed[src]
                .offline_decoding
                .retain(|&r| r != rid);
            self.cluster.requests[rid as usize].phase = Phase::Migrating;
            // Book the load on the instance that actually receives the KV
            // (the discharge paths — completion, eviction, drain
            // cancellation — all debit `inst`).
            self.cluster.router.decode_grow(inst, kv_len);
            self.cluster.strict[inst].inbound.push(rid);
            self.actions.push(Action::Migrate {
                req: rid,
                from_relaxed: src,
                to_strict: inst,
            });
            let moved = self.transfer_tokens_for(rid, &m);
            self.enqueue_transfer(
                rid,
                TransferKind::Migrate { to_strict: inst },
                moved,
            );
            self.cluster.migrations += 1;
        }
    }

    /// `online priority`: parked offline requests (prefilled on relaxed,
    /// waiting for strict space) move over as space frees — fit-only, no
    /// Algorithm 1.
    fn pull_parked_offline(&mut self, inst: usize) {
        if self.cfg.policy.offline_decode_on_relaxed()
            || self.cfg.policy == Policy::BasePd
            || !self.cluster.strict[inst].accepts_work()
        {
            return;
        }
        for src in 0..self.cluster.relaxed.len() {
            while let Some(&rid) =
                self.cluster.relaxed[src].offline_decoding.first()
            {
                let kv_len = self.cluster.requests[rid as usize].kv_len();
                if !self.cluster.strict[inst].kv.can_fit(kv_len + 1) {
                    return;
                }
                let m = self.peek_prefix(InstanceRef::Strict(inst), rid);
                self.admit_prefixed(
                    InstanceRef::Strict(inst),
                    rid,
                    kv_len + 1,
                    &m,
                );
                self.cluster.relaxed[src].kv.release(rid).expect("kv");
                self.cluster.relaxed[src]
                    .offline_decoding
                    .retain(|&r| r != rid);
                self.cluster.requests[rid as usize].phase = Phase::Migrating;
                // As in `maybe_pull_migration`: charge the receiving
                // instance, matching the decode_done debits.
                self.cluster.router.decode_grow(inst, kv_len);
                self.cluster.strict[inst].inbound.push(rid);
                let moved = self.transfer_tokens_for(rid, &m);
                self.enqueue_transfer(
                    rid,
                    TransferKind::Dispatch { to_strict: inst },
                    moved,
                );
            }
        }
    }

    /// A dispatched/migrated KV landed on strict instance `inst`: the
    /// request becomes a decode resident there.
    fn decode_handoff(&mut self, rid: RequestId, inst: usize) {
        self.cluster.strict[inst].inbound.retain(|&r| r != rid);
        // The landed chain is cacheable content at its new home.
        self.register_prefix(InstanceRef::Strict(inst), rid);
        let is_online = self.cluster.requests[rid as usize].class.is_online()
            || self.cfg.policy == Policy::BasePd;
        self.cluster.requests[rid as usize].phase = Phase::Decoding;
        if is_online {
            self.cluster.strict[inst].online.push(rid);
        } else {
            self.cluster.strict[inst].offline.push(rid);
        }
        self.start_strict_step(inst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Class;

    fn core_with(reqs: Vec<Request>) -> SchedulerCore {
        let cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        SchedulerCore::new(reqs, cfg)
    }

    #[test]
    fn online_arrival_starts_a_composed_prefill_step() {
        let mut core =
            core_with(vec![Request::new(0, Class::Online, 0.0, 500, 8)]);
        let actions = core.on_arrival(0.0, 0);
        match actions.as_slice() {
            [Action::StartStep {
                inst: InstanceRef::Relaxed(0),
                kind: StepKind::Composed,
                participants,
                prefill,
                ..
            }] => {
                assert!(participants.is_empty(), "no decode residents yet");
                assert_eq!(prefill.len(), 1);
                assert_eq!(prefill[0].req, 0);
                assert_eq!(prefill[0].tokens, 500);
                assert!(prefill[0].last, "500 tokens fit one chunk");
            }
            other => panic!("expected one composed step, got {other:?}"),
        }
        // The step is registered; a stale step-end seq is ignored.
        assert!(core.on_step_end(1.0, InstanceRef::Relaxed(0), 999).is_empty());
    }

    #[test]
    fn exclusive_mode_starts_legacy_prefill_step() {
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.serving.chunk_tokens = crate::config::ChunkMode::Off;
        let mut core = SchedulerCore::new(
            vec![Request::new(0, Class::Online, 0.0, 500, 8)],
            cfg,
        );
        let actions = core.on_arrival(0.0, 0);
        assert!(matches!(
            actions.as_slice(),
            [Action::StartStep {
                inst: InstanceRef::Relaxed(0),
                kind: StepKind::PrefillOnline,
                ..
            }]
        ));
    }

    #[test]
    fn long_prompt_prefills_across_multiple_chunks() {
        // A 4000-token offline prompt cannot fit one auto-budget chunk:
        // the cursor advances across iterations and TTFT lands at the
        // last chunk's boundary.
        let mut core =
            core_with(vec![Request::new(0, Class::Offline, 0.0, 4000, 4)]);
        let mut actions = core.on_arrival(0.0, 0);
        let mut t = 0.0;
        let mut chunks = 0usize;
        let mut total = 0usize;
        loop {
            let Some((seq, lat, tokens, last)) =
                actions.iter().find_map(|a| match a {
                    Action::StartStep {
                        inst: InstanceRef::Relaxed(0),
                        kind: StepKind::Composed,
                        prefill,
                        predicted_latency,
                        seq,
                        ..
                    } if !prefill.is_empty() => Some((
                        *seq,
                        *predicted_latency,
                        prefill[0].tokens,
                        prefill[0].last,
                    )),
                    _ => None,
                })
            else {
                break;
            };
            chunks += 1;
            total += tokens;
            assert!(chunks < 100, "runaway chunk loop");
            t += lat;
            actions = core.on_step_end(t, InstanceRef::Relaxed(0), seq);
            if last {
                assert!(core.cluster.requests[0].first_token_at.is_some());
                break;
            }
            assert!(
                core.cluster.requests[0].first_token_at.is_none(),
                "TTFT must wait for the last chunk"
            );
        }
        assert!(chunks > 1, "4000 tokens must take several chunks");
        assert_eq!(total, 4000, "chunks must cover the prompt exactly");
        assert_eq!(core.cluster.chunk_accounting_errors, 0);
        // The finished prefill decodes on the relaxed pool (OOCO).
        assert!(core.cluster.relaxed[0].offline_decoding.contains(&0));
        assert!(core.cluster.relaxed[0].prefilling.is_empty());
    }

    /// Drive every pending transfer chunk in `actions` (and the follow-up
    /// chunks they trigger) through the core, advancing a local clock;
    /// returns all actions the progress callbacks emitted.
    fn drive_chunks(
        core: &mut SchedulerCore,
        actions: &[Action],
        t0: f64,
    ) -> Vec<Action> {
        let mut pending: Vec<(u64, f64, u64)> = actions
            .iter()
            .filter_map(|a| match a {
                Action::TransferChunk {
                    job,
                    predicted_latency,
                    seq,
                    ..
                } => Some((*job, *predicted_latency, *seq)),
                _ => None,
            })
            .collect();
        let mut t = t0;
        let mut out = Vec::new();
        while let Some((job, dur, seq)) = pending.pop() {
            t += dur;
            let more = core.on_transfer_progress(t, job, seq);
            for a in &more {
                if let Action::TransferChunk {
                    job,
                    predicted_latency,
                    seq,
                    ..
                } = a
                {
                    pending.push((*job, *predicted_latency, *seq));
                }
            }
            out.extend(more);
        }
        out
    }

    #[test]
    fn prefill_completion_dispatches_to_strict() {
        let mut core =
            core_with(vec![Request::new(0, Class::Online, 0.0, 500, 8)]);
        let actions = core.on_arrival(0.0, 0);
        let Action::StartStep { seq, predicted_latency, .. } = &actions[0]
        else {
            panic!("expected StartStep");
        };
        let end = core.on_step_end(*predicted_latency, InstanceRef::Relaxed(0), *seq);
        assert!(
            end.iter()
                .any(|a| matches!(a, Action::TransferStart { req: 0, .. })),
            "prefill end must start a KV transfer job, got {end:?}"
        );
        assert!(
            end.iter()
                .any(|a| matches!(a, Action::TransferChunk { req: 0, .. })),
            "the idle pool link must issue the first chunk, got {end:?}"
        );
        // Driving all chunks to completion lands the KV on the strict
        // instance and starts its decode step.
        let landed = drive_chunks(&mut core, &end, *predicted_latency);
        assert!(
            landed
                .iter()
                .any(|a| matches!(a, Action::TransferDone { req: 0, .. })),
            "transfer must complete: {landed:?}"
        );
        assert!(
            landed.iter().any(|a| matches!(
                a,
                Action::StartStep {
                    inst: InstanceRef::Strict(0),
                    kind: StepKind::DecodeStrict,
                    ..
                }
            )),
            "strict decode must start after the last chunk: {landed:?}"
        );
    }

    #[test]
    fn strict_eviction_is_recoverable_not_discarded() {
        // An offline decode resident forced off the strict node streams its
        // KV into the relaxed pool (Rescue) instead of re-entering the
        // backlog for recompute.
        let mut core =
            core_with(vec![Request::new(0, Class::Offline, 0.0, 400, 64)]);
        // Place it on the strict node as Algorithm 1 would have.
        core.cluster.requests[0].mark_first_token(1.0);
        core.cluster.requests[0].phase = Phase::Decoding;
        let kv = core.cluster.requests[0].kv_len();
        core.cluster.strict[0].kv.admit(0, kv + 1).unwrap();
        core.cluster.strict[0].offline.push(0);
        core.cluster.kv_home[0] = KvHome::Strict(0);

        core.now = 5.0;
        core.evict_offline_from_strict(0, 0);
        let acts = std::mem::take(&mut core.actions);
        assert!(
            acts.iter().any(|a| matches!(
                a,
                Action::TransferStart {
                    req: 0,
                    kind: TransferKind::Rescue { .. },
                    ..
                }
            )),
            "recoverable eviction must stream KV out: {acts:?}"
        );
        assert!(
            !acts.iter().any(|a| matches!(a, Action::Evict { .. })),
            "no discard on the recoverable path: {acts:?}"
        );
        assert_eq!(core.cluster.rescues, 1);
        assert_eq!(core.cluster.requests[0].evictions, 0);
        assert_eq!(core.cluster.kv_home[0], KvHome::Relaxed(0));
        // Driving the rescue chunks lands it decoding on the relaxed pool
        // and records the preemption-to-restart latency.
        let landed = drive_chunks(&mut core, &acts, 5.0);
        assert!(landed
            .iter()
            .any(|a| matches!(a, Action::TransferDone { req: 0, .. })));
        assert!(core.cluster.relaxed[0].offline_decoding.contains(&0));
        assert_eq!(core.cluster.restart_latency.count(), 1);
        assert!(core.cluster.restart_latency.min() > 0.0);
        assert_eq!(core.cluster.requests[0].phase, Phase::Decoding);
    }

    #[test]
    fn offline_arrival_goes_through_gating_admit() {
        let mut core =
            core_with(vec![Request::new(0, Class::Offline, 0.0, 400, 16)]);
        let actions = core.on_arrival(0.0, 0);
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, Action::Admit { req: 0, .. })),
            "offline request must be gated-in on an idle cluster: {actions:?}"
        );
        assert!(actions.iter().any(|a| matches!(
            a,
            Action::StartStep {
                kind: StepKind::Composed,
                prefill,
                ..
            } if !prefill.is_empty()
        )));
    }

    #[test]
    fn base_pd_treats_offline_as_online() {
        let cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::BasePd);
        let mut core = SchedulerCore::new(
            vec![Request::new(0, Class::Offline, 0.0, 400, 16)],
            cfg,
        );
        let actions = core.on_arrival(0.0, 0);
        // Scheduled through the online path: a composed prefill step with
        // no gating Admit notification.
        assert!(!actions
            .iter()
            .any(|a| matches!(a, Action::Admit { .. })));
        assert!(matches!(
            actions.as_slice(),
            [Action::StartStep {
                kind: StepKind::Composed,
                ..
            }]
        ));
    }

    #[test]
    fn drain_flip_warm_relaxed_to_strict() {
        // 2 relaxed / 1 strict, idle cluster: drain the tail relaxed
        // instance and watch it flip + warm into the strict pool.
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.serving.cluster.relaxed_instances = 2;
        let mut core = SchedulerCore::new(Vec::new(), cfg);
        core.now = 10.0;
        core.start_drain(PoolRole::Relaxed);
        core.advance_transition(); // idle instance drains immediately
        let acts = std::mem::take(&mut core.actions);
        assert!(acts.iter().any(|a| matches!(
            a,
            Action::RoleChange {
                phase: RolePhase::Drain,
                inst: InstanceRef::Relaxed(1),
                to: PoolRole::Strict,
            }
        )));
        assert!(acts.iter().any(|a| matches!(
            a,
            Action::RoleChange {
                phase: RolePhase::Flip,
                inst: InstanceRef::Strict(1),
                ..
            }
        )));
        let (seq, warmup) = acts
            .iter()
            .find_map(|a| match a {
                Action::StartStep {
                    inst: InstanceRef::Strict(1),
                    kind: StepKind::Warm,
                    seq,
                    predicted_latency,
                    ..
                } => Some((*seq, *predicted_latency)),
                _ => None,
            })
            .expect("warm step must start on the flipped instance");
        assert_eq!(core.cluster.relaxed.len(), 1);
        assert_eq!(core.cluster.strict.len(), 2);
        assert_eq!(core.cluster.total_instances(), 3);
        assert!(core.pool.transition.is_some());
        // Warm completion ends the transition; the instance serves strict.
        let end = core.on_step_end(10.0 + warmup, InstanceRef::Strict(1), seq);
        assert!(end.iter().any(|a| matches!(
            a,
            Action::RoleChange {
                phase: RolePhase::Warm,
                inst: InstanceRef::Strict(1),
                ..
            }
        )));
        assert!(core.pool.transition.is_none());
        assert!(core.cluster.strict[1].is_idle());
        assert_eq!(core.pool_report().flips, 1);
    }

    #[test]
    fn draining_relaxed_instance_admits_no_new_work() {
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.serving.cluster.relaxed_instances = 2;
        let mut core = SchedulerCore::new(
            vec![
                Request::new(0, Class::Offline, 0.0, 400, 16),
                Request::new(1, Class::Online, 0.01, 500, 8),
            ],
            cfg,
        );
        core.now = 0.0;
        core.start_drain(PoolRole::Relaxed);
        // A straggler KV reservation keeps the instance in Drain phase
        // (idle but not flippable), so admission paths get exercised.
        core.cluster.relaxed[1].kv.admit(99, 100).unwrap();
        core.actions.clear();

        let a0 = core.on_arrival(0.0, 0);
        assert!(
            !a0.iter()
                .any(|a| matches!(a, Action::Admit { inst: 1, .. })),
            "gating must not admit onto the draining instance: {a0:?}"
        );
        let a1 = core.on_arrival(0.01, 1);
        for a in &a1 {
            if let Action::StartStep { inst, .. } = a {
                assert_ne!(
                    *inst,
                    InstanceRef::Relaxed(1),
                    "router must not start work on the draining instance"
                );
            }
        }
        assert!(core.cluster.relaxed[1].online_queue.is_empty());
        assert!(core.cluster.relaxed[1].offline_decoding.is_empty());
        // Still draining: the straggler KV blocks the flip.
        assert_eq!(core.cluster.relaxed.len(), 2);
        // Releasing it lets the next tick flip the instance.
        core.cluster.relaxed[1].kv.release(99).unwrap();
        core.advance_transition();
        assert_eq!(core.cluster.relaxed.len(), 1);
        assert_eq!(core.cluster.strict.len(), 2);
    }

    #[test]
    fn online_arrival_preempts_running_offline_prefill_exclusive() {
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.serving.chunk_tokens = crate::config::ChunkMode::Off;
        let mut core = SchedulerCore::new(
            vec![
                Request::new(0, Class::Offline, 0.0, 4000, 64),
                Request::new(1, Class::Online, 0.001, 500, 8),
            ],
            cfg,
        );
        let a0 = core.on_arrival(0.0, 0);
        assert!(a0.iter().any(|a| matches!(
            a,
            Action::StartStep {
                kind: StepKind::PrefillOffline,
                ..
            }
        )));
        let a1 = core.on_arrival(0.001, 1);
        assert!(
            a1.iter().any(|a| matches!(a, Action::Preempt { .. })),
            "online arrival mid-offline-prefill must preempt: {a1:?}"
        );
        assert_eq!(core.cluster.preemptions, 1);
    }

    #[test]
    fn chunked_preemption_retains_offline_progress() {
        // Chunk-granular fast preemption: an online arrival halts offline
        // chunk scheduling at the next iteration boundary, retaining the
        // cursor progress the exclusive-step truncation would discard —
        // and emits no Preempt (truncation) work order at all.
        let mut core = core_with(vec![
            Request::new(0, Class::Offline, 0.0, 4000, 64),
            Request::new(1, Class::Online, 0.0, 500, 8),
        ]);
        let a0 = core.on_arrival(0.0, 0);
        let (seq, lat) = a0
            .iter()
            .find_map(|a| match a {
                Action::StartStep {
                    kind: StepKind::Composed,
                    predicted_latency,
                    seq,
                    ..
                } => Some((*seq, *predicted_latency)),
                _ => None,
            })
            .expect("offline arrival must start a composed chunk step");
        // Finish the first chunk, then let the next chunk start.
        let a1 = core.on_step_end(lat, InstanceRef::Relaxed(0), seq);
        assert!(
            a1.iter().any(|a| matches!(
                a,
                Action::StartStep { kind: StepKind::Composed, .. }
            )),
            "offline prefill must continue chunking: {a1:?}"
        );
        let progressed = core.cluster.requests[0].prefilled_tokens;
        assert!(progressed > 0, "first chunk must advance the cursor");
        // Online arrival mid-(second)-chunk: chunk-granular preemption.
        let a2 = core.on_arrival(lat * 1.5, 1);
        assert!(
            !a2.iter().any(|a| matches!(a, Action::Preempt { .. })),
            "no truncation work order in chunked mode: {a2:?}"
        );
        assert_eq!(core.cluster.preemptions, 1);
        assert_eq!(
            core.cluster.chunk_retained_tokens,
            progressed as u64,
            "retained work = the cursor progress at the preemption"
        );
        assert_eq!(core.cluster.chunk_discarded_tokens, 0);
        // The retained cursor survives: the request is still mid-prefill.
        assert!(core.cluster.relaxed[0].prefilling.contains(&0));
        assert_eq!(core.cluster.requests[0].prefilled_tokens, progressed);
    }
}
