//! Execution substrates for [`SchedulerCore`].
//!
//! An [`Executor`] owns the clock and the machinery that *performs* the work
//! the core decides on: it delivers arrivals, runs (or simulates) steps, and
//! moves KV caches, invoking the core's entry points at its own step
//! boundaries and interpreting the returned [`Action`]s.
//!
//! Two library implementations:
//!
//! - [`VirtualExecutor`] — discrete-event queue + roofline-predicted
//!   latencies; the simulation substrate (`sim::simulate` is a shim over
//!   it). Steps "run" by scheduling their completion `predicted_latency`
//!   in the future.
//! - [`StubWallClockExecutor`] — an engine-shaped synchronous loop over a
//!   *stub* wall clock: work is executed one item at a time in completion
//!   order (linear-scan agenda, no heap) and the clock advances by the
//!   predicted latency, standing in for a measured execution. Used by the
//!   differential tests to prove the decision core is substrate-independent.
//!
//! The third implementation, `engine::EngineExecutor`, lives next to the
//! PJRT runtime it drives and uses a real wall clock and real model steps.

use crate::obs::{self, EventClass, Subsystem};
use crate::telemetry::TraceRecorder;
use crate::trace::Trace;

use super::action::{Action, InstanceRef};
use super::core::SchedulerCore;
use super::events::{EventKind, EventQueue, QueueKind};

/// Substrate-side outcome of driving a core to completion.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExecStats {
    /// Clock reading when the run ended (virtual seconds or wall seconds).
    pub end_time: f64,
    /// Entry-point invocations delivered to the core.
    pub events: u64,
}

/// The execution substrate behind a [`SchedulerCore`]: owns the clock,
/// delivers events, and carries out the core's [`Action`]s.
pub trait Executor {
    /// Current clock reading.
    fn now(&self) -> f64;

    /// Drive `core` until the workload drains (or the substrate's horizon
    /// passes). Entry points are invoked with this executor's clock.
    fn run(&mut self, core: &mut SchedulerCore) -> anyhow::Result<ExecStats>;
}

// --------------------------------------------------------------- virtual

/// Discrete-event substrate: a calendar event queue (heap-backed on
/// request, see [`QueueKind`]) on a virtual clock, with step/transfer
/// durations taken from the core's roofline predictions.
#[derive(Debug)]
pub struct VirtualExecutor {
    queue: EventQueue,
    now: f64,
    horizon: f64,
    events: u64,
    /// When `Some`, every action the core emits is appended — the
    /// observable stream asserted by the differential tests.
    pub log: Option<Vec<Action>>,
    /// Flight recorder tapping the same stream (disabled by default —
    /// a single branch per action batch).
    pub telemetry: TraceRecorder,
}

impl VirtualExecutor {
    /// Schedule `trace`'s arrivals; process events up to `horizon` seconds.
    pub fn new(trace: &Trace, horizon: f64) -> Self {
        Self::with_queue(trace, horizon, QueueKind::Calendar)
    }

    /// Like [`VirtualExecutor::new`] but on an explicit queue
    /// implementation — `tests/queue_differential.rs` drives both kinds
    /// over identical traces to pin the ordering contract.
    pub fn with_queue(trace: &Trace, horizon: f64, kind: QueueKind) -> Self {
        let _p = obs::scope(Subsystem::HeapPush);
        let mut queue = EventQueue::with_kind(kind);
        for r in &trace.requests {
            queue.push(r.arrival, EventKind::Arrival(r.id));
        }
        VirtualExecutor {
            queue,
            now: 0.0,
            horizon,
            events: 0,
            log: None,
            telemetry: TraceRecorder::disabled(),
        }
    }

    fn apply(&mut self, actions: &[Action]) {
        self.telemetry.observe(self.now, 0, actions);
        let _p = obs::scope(Subsystem::HeapPush);
        for a in actions {
            match *a {
                Action::StartStep {
                    inst,
                    predicted_latency,
                    seq,
                    ..
                } => {
                    let kind = match inst {
                        InstanceRef::Relaxed(i) => {
                            EventKind::RelaxedStep { inst: i, seq }
                        }
                        InstanceRef::Strict(i) => {
                            EventKind::StrictStep { inst: i, seq }
                        }
                    };
                    self.queue.push(self.now + predicted_latency, kind);
                }
                Action::Preempt { inst, delay, seq } => {
                    self.queue.push(
                        self.now + delay,
                        EventKind::RelaxedStep { inst, seq },
                    );
                }
                Action::TransferChunk {
                    job,
                    predicted_latency,
                    seq,
                    ..
                } => {
                    self.queue.push(
                        self.now + predicted_latency,
                        EventKind::TransferChunk { job, seq },
                    );
                }
                // Notifications: no virtual resources to manage.
                Action::TransferStart { .. }
                | Action::TransferDone { .. }
                | Action::TransferCancel { .. }
                | Action::Evict { .. }
                | Action::Migrate { .. }
                | Action::Admit { .. }
                | Action::PrefixResolve { .. }
                | Action::PrefixEvict { .. }
                | Action::Complete { .. }
                | Action::RepartitionPlan { .. }
                | Action::RoleChange { .. }
                | Action::InstanceDown { .. }
                | Action::InstanceUp { .. } => {}
            }
        }
    }
}

impl Executor for VirtualExecutor {
    fn now(&self) -> f64 {
        self.now
    }

    fn run(&mut self, core: &mut SchedulerCore) -> anyhow::Result<ExecStats> {
        loop {
            let ev = {
                let _p = obs::scope(Subsystem::HeapPop);
                match self.queue.pop() {
                    Some(ev) => ev,
                    None => break,
                }
            };
            if ev.time > self.horizon {
                break;
            }
            self.now = ev.time;
            self.events += 1;
            let mut actions = match ev.kind {
                EventKind::Arrival(rid) => {
                    obs::count_event(EventClass::Arrival);
                    let _p = obs::scope(Subsystem::Scheduler);
                    core.on_arrival(self.now, rid)
                }
                EventKind::RelaxedStep { inst, seq } => {
                    obs::count_event(EventClass::RelaxedStep);
                    let _p = obs::scope(Subsystem::Scheduler);
                    core.on_step_end(self.now, InstanceRef::Relaxed(inst), seq)
                }
                EventKind::StrictStep { inst, seq } => {
                    obs::count_event(EventClass::StrictStep);
                    let _p = obs::scope(Subsystem::Scheduler);
                    core.on_step_end(self.now, InstanceRef::Strict(inst), seq)
                }
                EventKind::TransferChunk { job, seq } => {
                    obs::count_event(EventClass::TransferChunk);
                    let _p = obs::scope(Subsystem::Transport);
                    core.on_transfer_progress(self.now, job, seq)
                }
            };
            self.apply(&actions);
            if let Some(log) = &mut self.log {
                // `append` moves the items but leaves `actions` its
                // capacity, which recycling below hands back to the core.
                log.append(&mut actions);
            }
            core.recycle_actions(actions);
            if self.telemetry.sample_due(self.now) {
                self.telemetry.sample_replica(
                    self.now,
                    0,
                    &core.cluster,
                    core.transport.links(),
                );
                self.telemetry.sample_tick(self.now, self.events);
            }
        }
        Ok(ExecStats {
            end_time: self.now,
            events: self.events,
        })
    }
}

// ------------------------------------------------------------- stub wall

/// Engine-shaped synchronous substrate over a stub wall clock.
///
/// Mirrors the real engine's control structure — one work item executed at a
/// time, completion observed, then the next item picked — but the "measured"
/// duration of each item is the core's prediction, and the agenda is a flat
/// linear-scan list rather than a heap. Because the decision core is shared
/// and its clock inputs coincide, the emitted action stream must be
/// *identical* to [`VirtualExecutor`]'s; `tests/scheduler_differential.rs`
/// asserts exactly that for all three policies.
#[derive(Debug)]
pub struct StubWallClockExecutor {
    agenda: Vec<AgendaItem>,
    next_tie: u64,
    now: f64,
    horizon: f64,
    events: u64,
    /// When `Some`, records the core's emitted actions.
    pub log: Option<Vec<Action>>,
}

#[derive(Debug, Clone, Copy)]
struct AgendaItem {
    time: f64,
    tie: u64,
    kind: EventKind,
}

impl StubWallClockExecutor {
    pub fn new(trace: &Trace, horizon: f64) -> Self {
        let mut agenda = Vec::with_capacity(trace.requests.len());
        let mut next_tie = 0u64;
        for r in &trace.requests {
            agenda.push(AgendaItem {
                time: r.arrival,
                tie: next_tie,
                kind: EventKind::Arrival(r.id),
            });
            next_tie += 1;
        }
        StubWallClockExecutor {
            agenda,
            next_tie,
            now: 0.0,
            horizon,
            events: 0,
            log: None,
        }
    }

    fn push(&mut self, time: f64, kind: EventKind) {
        let tie = self.next_tie;
        self.next_tie += 1;
        self.agenda.push(AgendaItem { time, tie, kind });
    }

    /// Earliest agenda item by (time, insertion order) via linear scan.
    fn take_next(&mut self) -> Option<AgendaItem> {
        if self.agenda.is_empty() {
            return None;
        }
        let mut best = 0usize;
        for i in 1..self.agenda.len() {
            let (a, b) = (&self.agenda[i], &self.agenda[best]);
            if a.time < b.time || (a.time == b.time && a.tie < b.tie) {
                best = i;
            }
        }
        Some(self.agenda.swap_remove(best))
    }

    fn apply(&mut self, actions: Vec<Action>) {
        for a in &actions {
            match *a {
                Action::StartStep {
                    inst,
                    predicted_latency,
                    seq,
                    ..
                } => {
                    // "Execute" the step: its completion lands on the stub
                    // wall clock after the predicted (stand-in measured)
                    // duration.
                    let kind = match inst {
                        InstanceRef::Relaxed(i) => {
                            EventKind::RelaxedStep { inst: i, seq }
                        }
                        InstanceRef::Strict(i) => {
                            EventKind::StrictStep { inst: i, seq }
                        }
                    };
                    self.push(self.now + predicted_latency, kind);
                }
                Action::Preempt { inst, delay, seq } => {
                    self.push(
                        self.now + delay,
                        EventKind::RelaxedStep { inst, seq },
                    );
                }
                Action::TransferChunk {
                    job,
                    predicted_latency,
                    seq,
                    ..
                } => {
                    self.push(
                        self.now + predicted_latency,
                        EventKind::TransferChunk { job, seq },
                    );
                }
                Action::TransferStart { .. }
                | Action::TransferDone { .. }
                | Action::TransferCancel { .. }
                | Action::Evict { .. }
                | Action::Migrate { .. }
                | Action::Admit { .. }
                | Action::PrefixResolve { .. }
                | Action::PrefixEvict { .. }
                | Action::Complete { .. }
                | Action::RepartitionPlan { .. }
                | Action::RoleChange { .. }
                | Action::InstanceDown { .. }
                | Action::InstanceUp { .. } => {}
            }
        }
        if let Some(log) = &mut self.log {
            log.extend(actions);
        }
    }
}

impl Executor for StubWallClockExecutor {
    fn now(&self) -> f64 {
        self.now
    }

    fn run(&mut self, core: &mut SchedulerCore) -> anyhow::Result<ExecStats> {
        while let Some(item) = self.take_next() {
            if item.time > self.horizon {
                break;
            }
            // The stub wall clock only moves forward.
            self.now = self.now.max(item.time);
            self.events += 1;
            let actions = match item.kind {
                EventKind::Arrival(rid) => core.on_arrival(self.now, rid),
                EventKind::RelaxedStep { inst, seq } => {
                    core.on_step_end(self.now, InstanceRef::Relaxed(inst), seq)
                }
                EventKind::StrictStep { inst, seq } => {
                    core.on_step_end(self.now, InstanceRef::Strict(inst), seq)
                }
                EventKind::TransferChunk { job, seq } => {
                    core.on_transfer_progress(self.now, job, seq)
                }
            };
            self.apply(actions);
        }
        Ok(ExecStats {
            end_time: self.now,
            events: self.events,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::coordinator::Policy;
    use crate::request::{Class, Request};
    use crate::scheduler::CoreConfig;

    fn tiny_trace() -> Trace {
        let mut reqs = Vec::new();
        for i in 0..4u64 {
            reqs.push(Request::new(i, Class::Online, 0.2 * i as f64, 300, 6));
        }
        for i in 4..8u64 {
            reqs.push(Request::new(
                i,
                Class::Offline,
                0.15 * (i - 4) as f64 + 0.05,
                600,
                10,
            ));
        }
        Trace::new(reqs)
    }

    fn run_with<E: Executor>(mut ex: E) -> (SchedulerCore, ExecStats, E) {
        let cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        let mut core = SchedulerCore::new(tiny_trace().requests, cfg);
        let stats = ex.run(&mut core).unwrap();
        (core, stats, ex)
    }

    #[test]
    fn virtual_executor_drains_tiny_trace() {
        let ex = VirtualExecutor::new(&tiny_trace(), 1e6);
        let (core, stats, _) = run_with(ex);
        assert!(core.cluster.drained(), "cluster must drain");
        assert!(stats.events > 8, "events {}", stats.events);
        assert!(core
            .cluster
            .requests
            .iter()
            .all(|r| r.finished_at.is_some()));
    }

    #[test]
    fn stub_executor_matches_virtual_stream() {
        let trace = tiny_trace();
        let cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);

        let mut virt = VirtualExecutor::new(&trace, 1e6);
        virt.log = Some(Vec::new());
        let mut core_v = SchedulerCore::new(trace.requests.clone(), cfg.clone());
        virt.run(&mut core_v).unwrap();

        let mut stub = StubWallClockExecutor::new(&trace, 1e6);
        stub.log = Some(Vec::new());
        let mut core_s = SchedulerCore::new(trace.requests.clone(), cfg);
        stub.run(&mut core_s).unwrap();

        assert_eq!(virt.log, stub.log, "action streams must be identical");
        assert!(core_s.cluster.drained());
    }
}
