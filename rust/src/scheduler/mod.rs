//! Unified scheduling subsystem — one §3.4 decision loop driving both the
//! simulator and the real engine.
//!
//! The paper's central architectural claim is that the simulator and the
//! real engine exercise *the same* scheduling code; this module makes that
//! structural. It splits serving into three roles:
//!
//! - [`ClusterState`] — pure state: the two latency-constraint pools, the
//!   shared offline backlog, per-request KV residency, and the router;
//! - [`SchedulerCore`] — the decision loop: three step-boundary entry
//!   points ([`SchedulerCore::on_arrival`], [`SchedulerCore::on_step_end`],
//!   [`SchedulerCore::on_transfer_progress`]) that fold the four
//!   coordinator scheduling points (gating, migration, mix-decode,
//!   preemption) into typed [`Action`]s, with the embedded
//!   [`crate::transport::TransportEngine`] timing every KV movement;
//! - [`Executor`] — the substrate: owns the clock, executes the actions,
//!   and calls back into the core at its own step boundaries.
//!
//! Two executors ship here ([`VirtualExecutor`] on a discrete-event virtual
//! clock, [`StubWallClockExecutor`] as an engine-shaped verification
//! harness); the real `engine::EngineExecutor` lives with the PJRT runtime
//! it drives. `sim::simulate` and `engine::serve_trace_with_runtime` are
//! thin compatibility shims over this module. New policies, substrates
//! (multi-GPU, sharded), and workloads plug in as `Executor`/`Action`
//! implementations instead of a third copy of the loop. See DESIGN.md §3.
//!
//! The low-level decision *functions* stay in [`crate::coordinator`] as
//! pure math; this module re-exports them so every scheduling call site can
//! import through `scheduler::` — outside this subsystem nothing needs to
//! reach into `coordinator::` directly.

pub mod action;
pub mod cluster;
pub mod core;
pub mod events;
pub mod executor;

pub use self::action::{Action, InstanceRef, RolePhase};
pub use self::cluster::{ClusterState, KvHome};
pub use self::core::{CoreConfig, SchedulerCore};
pub use self::events::{
    CalendarQueue, Event, EventKind, EventQueue, HeapQueue, OrderedTime,
    QueueKind, TimeQueue, TimedEvent,
};
pub use self::executor::{
    ExecStats, Executor, StubWallClockExecutor, VirtualExecutor,
};

// The KV transport vocabulary actions and events speak, re-exported for
// the same single-surface reason.
pub use crate::transport::{JobId, TransferKind, TransportEngine};

// The pool-role vocabulary of the elastic pool manager (DESIGN.md §3.6),
// whose plan/transition decisions ride on this module's action stream —
// plus the iteration-composition vocabulary of the chunked-prefill model
// (DESIGN.md §3.8) the `StartStep` actions carry.
pub use crate::instance::{PoolRole, PrefillSegment, Step, StepKind};
pub use crate::pool::{PoolManager, PoolPlan};

// The underlying §3.4 decision functions, re-exported so all scheduling
// call sites (benches, tests, tools) go through the `scheduler` surface.
pub use crate::coordinator::{
    migration_decision, pick_migration_candidates, preemption_delay,
    select_decode_batch, select_decode_batch_capped, select_evictions,
    shed_online_overload, should_prefill_offline, Ablation, Candidate,
    GatingInput, LengthPref, OverloadMode, Policy, Selection,
};
