//! Discrete-event time queues for the virtual-clock executors
//! (DESIGN.md §3.13; moved here from `sim::events`, which re-exports it
//! for compatibility).
//!
//! Two interchangeable implementations behind [`TimeQueue`], both honoring
//! the exact same ordering contract — events pop in ascending
//! `(time, insertion order)`, so two queues fed the same push sequence
//! deliver byte-identical schedules:
//!
//! - [`CalendarQueue`] (the default): a calendar/bucket queue with an
//!   overflow heap. The near-horizon band that dominates LLM-serving event
//!   streams (step ends and transfer chunks all land within a few hundred
//!   milliseconds of *now*) maps to an array of time buckets with O(1)
//!   amortized push and pop; far-future events (diurnal arrivals, fault
//!   schedules) wait in a binary heap and are decanted band by band.
//! - [`HeapQueue`]: the classic `BinaryHeap` queue — the pre-calendar
//!   implementation, kept as the differential baseline
//!   (`tests/queue_differential.rs`) and as an escape hatch.
//!
//! Time ordering goes through [`OrderedTime`], a total-order newtype that
//! asserts finiteness at construction — the old
//! `partial_cmp(..).unwrap_or(Ordering::Equal)` silently treated a NaN
//! event time as equal to everything, corrupting the schedule; now it
//! fails loudly at the push that produced it.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::request::RequestId;
use crate::transport::JobId;

// ---------------------------------------------------------- ordered time

/// Total-order wrapper for event timestamps: construction asserts the
/// value is finite, which makes `Ord` safe to build on `partial_cmp`.
/// Both event loops (`scheduler` and `fleet`) order through this type, so
/// their tie-breaking semantics cannot drift.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrderedTime(f64);

impl OrderedTime {
    #[inline]
    pub fn new(t: f64) -> Self {
        assert!(t.is_finite(), "non-finite event time: {t}");
        OrderedTime(t)
    }

    #[inline]
    pub fn get(self) -> f64 {
        self.0
    }
}

impl Eq for OrderedTime {}

impl Ord for OrderedTime {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        // Finiteness is asserted at construction, so partial_cmp is total.
        self.0.partial_cmp(&other.0).expect("finite by construction")
    }
}

impl PartialOrd for OrderedTime {
    #[inline]
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

// --------------------------------------------------------------- events

/// Simulation event kinds of the single-cluster loop. Step and transfer
/// events carry the sequence id current when they were scheduled;
/// completions whose seq no longer matches (superseded by a preemption
/// reschedule or a pool flip) are dropped by the core — the generational
/// staleness guard of DESIGN.md §3.13.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A request arrives at the cluster.
    Arrival(RequestId),
    /// A relaxed instance's step (with `seq`) finishes.
    RelaxedStep { inst: usize, seq: u64 },
    /// A strict instance's step finishes.
    StrictStep { inst: usize, seq: u64 },
    /// One chunk of a KV transfer job completes on its link.
    TransferChunk { job: JobId, seq: u64 },
}

/// A scheduled event: fire time plus a monotone insertion tie so equal
/// times pop in push order — the deterministic ordering contract every
/// executor, telemetry stream, and differential test relies on.
#[derive(Debug, Clone, Copy)]
pub struct TimedEvent<K> {
    pub time: f64,
    pub tie: u64,
    pub kind: K,
}

impl<K> PartialEq for TimedEvent<K> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.tie == other.tie
    }
}

impl<K> Eq for TimedEvent<K> {}

impl<K> Ord for TimedEvent<K> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse order: BinaryHeap is a max-heap, we want earliest first.
        OrderedTime::new(other.time)
            .cmp(&OrderedTime::new(self.time))
            .then(other.tie.cmp(&self.tie))
    }
}

impl<K> PartialOrd for TimedEvent<K> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The single-cluster loop's event type.
pub type Event = TimedEvent<EventKind>;

// ----------------------------------------------------------- heap queue

/// The classic binary-heap time queue — O(log n) push/pop.
#[derive(Debug)]
pub struct HeapQueue<K> {
    heap: BinaryHeap<TimedEvent<K>>,
    next_tie: u64,
}

impl<K> Default for HeapQueue<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K> HeapQueue<K> {
    pub fn new() -> Self {
        HeapQueue {
            heap: BinaryHeap::new(),
            next_tie: 0,
        }
    }

    pub fn push(&mut self, time: f64, kind: K) {
        let _ = OrderedTime::new(time);
        let tie = self.next_tie;
        self.next_tie += 1;
        self.heap.push(TimedEvent { time, tie, kind });
    }

    pub fn pop(&mut self) -> Option<TimedEvent<K>> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

// ------------------------------------------------------- calendar queue

/// Buckets per band. With the width tracking the mean inter-event gap the
/// expected bucket occupancy is ~1, making push and pop O(1) amortized.
const CAL_BUCKETS: usize = 1024;
/// Bucket width floor (seconds) — guards against a denormal gap EWMA.
const MIN_WIDTH: f64 = 1e-9;
/// Width used before any inter-event gap has been observed.
const DEFAULT_WIDTH: f64 = 1e-3;

/// Calendar/bucket event queue with an overflow heap.
///
/// The *band* is a window `[base, base + CAL_BUCKETS × width)` divided
/// into `CAL_BUCKETS` buckets; events inside it live in their bucket's
/// unordered vec, events at or beyond its end wait in the overflow heap.
/// A cursor walks the buckets forward; when the band drains, the queue
/// re-bases on the earliest overflow event and decants the next window.
/// The bucket width adapts to an EWMA of observed inter-pop gaps — a
/// deterministic function of the popped schedule, so same-seed runs build
/// identical calendars (width only affects speed, never order).
///
/// ## Ordering exactness (DESIGN.md §3.13)
///
/// Pops are exactly ascending `(time, insertion tie)`:
///
/// - The bucket index `floor((t − base) / width)` is monotone in `t`
///   (IEEE-754 subtraction and division by a positive constant preserve
///   order), so an earlier-time event can never land in a later bucket
///   and equal times always share a bucket — the linear min-scan of the
///   cursor bucket therefore yields the global minimum.
/// - Events whose computed index falls behind the cursor (possible only
///   within rounding noise of a bucket edge, since pushed times never
///   precede the last pop) clamp *to* the cursor bucket, which is
///   scanned first.
/// - The same index formula splits band from overflow, so every overflow
///   event is ≥ every band event and the band always drains first.
#[derive(Debug)]
pub struct CalendarQueue<K> {
    buckets: Vec<Vec<TimedEvent<K>>>,
    /// Left edge of bucket 0 for the current band.
    base: f64,
    width: f64,
    /// First possibly non-empty bucket; only advances within a band.
    cursor: usize,
    /// Events currently in buckets.
    in_band: usize,
    /// Events at or beyond the band end.
    overflow: BinaryHeap<TimedEvent<K>>,
    /// False until the first rebuild establishes a band.
    band_active: bool,
    /// EWMA of positive inter-pop gaps; 0 until the first gap.
    gap_ewma: f64,
    last_pop: f64,
    has_popped: bool,
    next_tie: u64,
    len: usize,
}

impl<K> Default for CalendarQueue<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K> CalendarQueue<K> {
    pub fn new() -> Self {
        CalendarQueue {
            buckets: (0..CAL_BUCKETS).map(|_| Vec::new()).collect(),
            base: 0.0,
            width: DEFAULT_WIDTH,
            cursor: 0,
            in_band: 0,
            overflow: BinaryHeap::new(),
            band_active: false,
            gap_ewma: 0.0,
            last_pop: 0.0,
            has_popped: false,
            next_tie: 0,
            len: 0,
        }
    }

    /// Bucket index for `time` under the current band, `None` when the
    /// time falls at or beyond the band end. The single monotone formula
    /// both `push` and the rebuild decant use — see the ordering argument.
    #[inline]
    fn slot(&self, time: f64) -> Option<usize> {
        let rel = (time - self.base) / self.width;
        if rel >= CAL_BUCKETS as f64 {
            None
        } else {
            Some(rel.max(0.0) as usize)
        }
    }

    pub fn push(&mut self, time: f64, kind: K) {
        let _ = OrderedTime::new(time);
        let tie = self.next_tie;
        self.next_tie += 1;
        let ev = TimedEvent { time, tie, kind };
        self.len += 1;
        if self.band_active {
            if let Some(i) = self.slot(time) {
                // Behind-the-cursor indexes (bucket-edge rounding noise)
                // clamp to the cursor bucket, which pops first.
                let i = i.max(self.cursor);
                self.buckets[i].push(ev);
                self.in_band += 1;
                return;
            }
        }
        self.overflow.push(ev);
    }

    /// Re-base the band on the earliest overflow event and decant every
    /// overflow event that falls inside the new window.
    fn rebuild(&mut self) {
        debug_assert_eq!(self.in_band, 0);
        let Some(head) = self.overflow.peek() else {
            self.band_active = false;
            return;
        };
        self.base = head.time;
        self.width = if self.gap_ewma > 0.0 {
            self.gap_ewma.max(MIN_WIDTH)
        } else {
            DEFAULT_WIDTH
        };
        self.cursor = 0;
        self.band_active = true;
        while let Some(head) = self.overflow.peek() {
            let Some(i) = self.slot(head.time) else { break };
            let ev = self.overflow.pop().expect("peeked");
            self.buckets[i].push(ev);
            self.in_band += 1;
        }
    }

    pub fn pop(&mut self) -> Option<TimedEvent<K>> {
        if self.len == 0 {
            return None;
        }
        if self.in_band == 0 {
            // The overflow head lands at rel = 0, so the decant always
            // moves at least one event into the band.
            self.rebuild();
            debug_assert!(self.in_band > 0);
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
            debug_assert!(self.cursor < CAL_BUCKETS, "in_band desynced");
        }
        let bucket = &mut self.buckets[self.cursor];
        // Full (time, tie) min-scan: order-independent, so swap_remove's
        // reshuffling of the bucket cannot perturb pop order.
        let mut best = 0usize;
        for (j, e) in bucket.iter().enumerate().skip(1) {
            let b = &bucket[best];
            if e.time < b.time || (e.time == b.time && e.tie < b.tie) {
                best = j;
            }
        }
        let ev = bucket.swap_remove(best);
        self.in_band -= 1;
        self.len -= 1;
        // Deterministic width adaptation from observed inter-pop gaps.
        if self.has_popped {
            let gap = ev.time - self.last_pop;
            if gap > 0.0 {
                self.gap_ewma = if self.gap_ewma > 0.0 {
                    0.875 * self.gap_ewma + 0.125 * gap
                } else {
                    gap
                };
            }
        }
        self.last_pop = ev.time;
        self.has_popped = true;
        Some(ev)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

// ------------------------------------------------------- queue selector

/// Which time-queue implementation an executor runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// Calendar/bucket queue with overflow heap (the default).
    #[default]
    Calendar,
    /// Plain binary-heap queue — differential baseline and escape hatch.
    BinaryHeap,
}

impl QueueKind {
    pub fn name(self) -> &'static str {
        match self {
            QueueKind::Calendar => "calendar",
            QueueKind::BinaryHeap => "heap",
        }
    }
}

/// A time queue of either implementation; both honor the identical
/// `(time, insertion order)` pop contract, enforced by
/// `tests/queue_differential.rs`.
#[derive(Debug)]
pub enum TimeQueue<K> {
    Calendar(CalendarQueue<K>),
    Heap(HeapQueue<K>),
}

impl<K> TimeQueue<K> {
    pub fn new() -> Self {
        Self::with_kind(QueueKind::Calendar)
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        match kind {
            QueueKind::Calendar => TimeQueue::Calendar(CalendarQueue::new()),
            QueueKind::BinaryHeap => TimeQueue::Heap(HeapQueue::new()),
        }
    }

    pub fn kind(&self) -> QueueKind {
        match self {
            TimeQueue::Calendar(_) => QueueKind::Calendar,
            TimeQueue::Heap(_) => QueueKind::BinaryHeap,
        }
    }

    #[inline]
    pub fn push(&mut self, time: f64, kind: K) {
        match self {
            TimeQueue::Calendar(q) => q.push(time, kind),
            TimeQueue::Heap(q) => q.push(time, kind),
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<TimedEvent<K>> {
        match self {
            TimeQueue::Calendar(q) => q.pop(),
            TimeQueue::Heap(q) => q.pop(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TimeQueue::Calendar(q) => q.len(),
            TimeQueue::Heap(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<K> Default for TimeQueue<K> {
    fn default() -> Self {
        Self::new()
    }
}

/// The single-cluster executor's queue type (calendar by default).
pub type EventQueue = TimeQueue<EventKind>;

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut TimeQueue<u32>) -> Vec<(f64, u64, u32)> {
        let mut out = Vec::new();
        while let Some(ev) = q.pop() {
            out.push((ev.time, ev.tie, ev.kind));
        }
        out
    }

    #[test]
    fn pops_in_time_order() {
        for kind in [QueueKind::Calendar, QueueKind::BinaryHeap] {
            let mut q = EventQueue::with_kind(kind);
            q.push(3.0, EventKind::Arrival(3));
            q.push(1.0, EventKind::Arrival(1));
            q.push(2.0, EventKind::Arrival(2));
            let order: Vec<f64> =
                std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
            assert_eq!(order, vec![1.0, 2.0, 3.0], "{kind:?}");
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in [QueueKind::Calendar, QueueKind::BinaryHeap] {
            let mut q = EventQueue::with_kind(kind);
            q.push(1.0, EventKind::Arrival(10));
            q.push(1.0, EventKind::Arrival(20));
            q.push(1.0, EventKind::Arrival(30));
            let ids: Vec<RequestId> = std::iter::from_fn(|| q.pop())
                .map(|e| match e.kind {
                    EventKind::Arrival(r) => r,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(ids, vec![10, 20, 30], "{kind:?}");
        }
    }

    #[test]
    fn len_tracking() {
        for kind in [QueueKind::Calendar, QueueKind::BinaryHeap] {
            let mut q = EventQueue::with_kind(kind);
            assert!(q.is_empty());
            q.push(1.0, EventKind::Arrival(0));
            q.push(2.0, EventKind::StrictStep { inst: 0, seq: 1 });
            assert_eq!(q.len(), 2, "{kind:?}");
            q.pop();
            assert_eq!(q.len(), 1, "{kind:?}");
        }
    }

    #[test]
    fn calendar_crosses_bands_in_exact_order() {
        // Events spread far beyond one band window force repeated
        // rebuilds; the pop order must stay exactly (time, tie).
        let mut q: TimeQueue<u32> = TimeQueue::with_kind(QueueKind::Calendar);
        for i in 0..500u32 {
            q.push(f64::from(i % 100) * 7.3 + 0.0005 * f64::from(i % 7), i);
        }
        let popped = drain(&mut q);
        assert_eq!(popped.len(), 500);
        let mut sorted = popped.clone();
        sorted.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        assert_eq!(popped, sorted);
    }

    #[test]
    fn calendar_interleaved_push_pop_matches_heap() {
        // Randomized differential: the same deterministic push/pop script
        // against both queues must yield identical (time, tie) sequences.
        // Pushes are monotone-from-now like the simulator's `now + lat`.
        let mut cal: TimeQueue<u32> = TimeQueue::with_kind(QueueKind::Calendar);
        let mut heap: TimeQueue<u32> =
            TimeQueue::with_kind(QueueKind::BinaryHeap);
        let mut rng: u64 = 0x9E3779B97F4A7C15;
        let mut next = move |m: u64| {
            rng = rng
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (rng >> 33) % m
        };
        let mut now = 0.0f64;
        let mut k = 0u32;
        for _ in 0..200 {
            // Burst of pushes with latencies spanning six orders of
            // magnitude, including deliberate exact-duplicate times.
            let burst = 1 + next(8);
            for _ in 0..burst {
                let lat = match next(4) {
                    0 => 0.0,
                    1 => 1e-4 * (1 + next(50)) as f64,
                    2 => 0.05 * (1 + next(20)) as f64,
                    _ => 10.0 * (1 + next(100)) as f64,
                };
                cal.push(now + lat, k);
                heap.push(now + lat, k);
                k += 1;
            }
            let drains = 1 + next(6);
            for _ in 0..drains {
                match (cal.pop(), heap.pop()) {
                    (Some(a), Some(b)) => {
                        assert_eq!(
                            (a.time, a.tie, a.kind),
                            (b.time, b.tie, b.kind)
                        );
                        now = a.time;
                    }
                    (None, None) => break,
                    other => panic!("queues disagree on emptiness: {other:?}"),
                }
            }
        }
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_is_rejected_at_push() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, EventKind::Arrival(0));
    }

    #[test]
    fn ordered_time_totality() {
        assert!(OrderedTime::new(1.0) < OrderedTime::new(2.0));
        assert_eq!(OrderedTime::new(3.5), OrderedTime::new(3.5));
        assert_eq!(OrderedTime::new(7.25).get(), 7.25);
    }
}
