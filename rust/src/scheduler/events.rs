//! Event queue for virtual-clock executors (moved here from `sim::events`;
//! `sim` re-exports it for compatibility).
//!
//! A binary min-heap keyed on (time, insertion order). The tie-breaking
//! sequence number makes the simulation fully deterministic regardless of
//! float equality between event times.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::request::RequestId;
use crate::transport::JobId;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A request arrives at the cluster.
    Arrival(RequestId),
    /// A relaxed instance's step (with `seq`) finishes.
    RelaxedStep { inst: usize, seq: u64 },
    /// A strict instance's step finishes.
    StrictStep { inst: usize, seq: u64 },
    /// One chunk of a KV transfer job completes on its link.
    TransferChunk { job: JobId, seq: u64 },
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: f64,
    pub tie: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.tie == other.tie
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse order: BinaryHeap is a max-heap, we want earliest first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.tie.cmp(&self.tie))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-heap of simulation events.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_tie: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "non-finite event time");
        let tie = self.next_tie;
        self.next_tie += 1;
        self.heap.push(Event { time, tie, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::Arrival(3));
        q.push(1.0, EventKind::Arrival(1));
        q.push(2.0, EventKind::Arrival(2));
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Arrival(10));
        q.push(1.0, EventKind::Arrival(20));
        q.push(1.0, EventKind::Arrival(30));
        let ids: Vec<RequestId> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EventKind::Arrival(r) => r,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![10, 20, 30]);
    }

    #[test]
    fn len_tracking() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, EventKind::Arrival(0));
        q.push(2.0, EventKind::StrictStep { inst: 0, seq: 1 });
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }
}
