//! Transfer jobs: one KV cache moving over one link, split into
//! layer-wise chunks (§3.4.1 granularity — a layer's KV is the natural
//! unit that can stream out while later layers still compute).

use crate::request::RequestId;

/// Unique id of one transfer job within a [`super::TransportEngine`].
pub type JobId = u64;

/// The five KV movements of the system, naming the destination the
/// scheduler hands the request to once the last chunk lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// Post-prefill decode dispatch: relaxed -> strict.
    Dispatch { to_strict: usize },
    /// Algorithm 1 migration pull: relaxed -> strict.
    Migrate { to_strict: usize },
    /// Recoverable fast preemption: an evicted offline decode streams its
    /// KV from a strict node into the relaxed pool instead of discarding.
    Rescue { to_relaxed: usize },
    /// Recoverable fast preemption: evicted KV streams to host staging.
    Offload,
    /// Staged KV streams back from host to a relaxed instance.
    Restore { to_relaxed: usize },
}

impl TransferKind {
    /// Which named link carries this movement.
    pub fn link(self) -> usize {
        match self {
            TransferKind::Offload | TransferKind::Restore { .. } => {
                super::HOST_LINK
            }
            _ => super::POOL_LINK,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransferKind::Dispatch { .. } => "dispatch",
            TransferKind::Migrate { .. } => "migrate",
            TransferKind::Rescue { .. } => "rescue",
            TransferKind::Offload => "offload",
            TransferKind::Restore { .. } => "restore",
        }
    }
}

/// One KV cache in flight: fixed chunk plan plus progress.
#[derive(Debug, Clone)]
pub struct TransferJob {
    pub id: JobId,
    pub req: RequestId,
    pub kind: TransferKind,
    /// Link index in the engine's topology.
    pub link: usize,
    /// KV tokens being moved (fixed at enqueue; the request does not decode
    /// while migrating).
    pub kv_tokens: usize,
    pub total_bytes: f64,
    /// Bytes per chunk (`total_bytes / chunks`).
    pub chunk_bytes: f64,
    pub chunks: usize,
    pub chunks_done: usize,
    pub enqueued_at: f64,
    /// Set by [`super::TransportEngine::cancel`] while a chunk is still in
    /// flight; the job is reaped when that chunk's completion fires.
    pub cancelled: bool,
}

impl TransferJob {
    pub fn remaining_bytes(&self) -> f64 {
        self.total_bytes - self.chunks_done as f64 * self.chunk_bytes
    }

    pub fn is_done(&self) -> bool {
        self.chunks_done >= self.chunks
    }
}

/// Work order for the executor: deliver
/// [`crate::scheduler::SchedulerCore::on_transfer_progress`] with
/// (`job`, `seq`) once `duration` has elapsed on its clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkOrder {
    pub job: JobId,
    pub req: RequestId,
    pub link: usize,
    /// Index of the chunk being served (0-based).
    pub chunk: usize,
    /// Service time: link setup latency + chunk bytes / bandwidth.
    pub duration: f64,
    /// Staleness guard: the engine ignores completions whose seq does not
    /// match the link's outstanding chunk.
    pub seq: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_links_and_names() {
        assert_eq!(TransferKind::Dispatch { to_strict: 0 }.link(), 0);
        assert_eq!(TransferKind::Migrate { to_strict: 1 }.link(), 0);
        assert_eq!(TransferKind::Rescue { to_relaxed: 0 }.link(), 0);
        assert_eq!(TransferKind::Offload.link(), 1);
        assert_eq!(TransferKind::Restore { to_relaxed: 0 }.link(), 1);
        assert_eq!(TransferKind::Offload.name(), "offload");
    }

    #[test]
    fn job_progress_accounting() {
        let mut j = TransferJob {
            id: 1,
            req: 7,
            kind: TransferKind::Offload,
            link: 1,
            kv_tokens: 100,
            total_bytes: 400.0,
            chunk_bytes: 100.0,
            chunks: 4,
            chunks_done: 0,
            enqueued_at: 0.0,
            cancelled: false,
        };
        assert_eq!(j.remaining_bytes(), 400.0);
        j.chunks_done = 3;
        assert_eq!(j.remaining_bytes(), 100.0);
        assert!(!j.is_done());
        j.chunks_done = 4;
        assert!(j.is_done());
    }
}
