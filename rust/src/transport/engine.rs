//! [`TransportEngine`] — deterministic queueing engine for chunked KV
//! transfers over the configured link topology.
//!
//! The engine is clock-free in the same sense as the scheduler core: time
//! enters only through the `now` argument of [`TransportEngine::enqueue`]
//! and [`TransportEngine::on_chunk_done`], and every timed obligation
//! leaves as a [`ChunkOrder`] the surrounding executor must deliver back.
//! Each link serves one chunk at a time, so concurrent jobs *contend*:
//! under FIFO a job owns the link until its last chunk; under fair-share
//! active jobs round-robin chunk-by-chunk. Either way per-link completions
//! are monotone in time and total bytes are conserved
//! (`tests/transport_properties.rs`).

use std::collections::HashMap;

use crate::config::{LinkSharing, TransportSpec};
use crate::request::RequestId;

use super::job::{ChunkOrder, JobId, TransferJob, TransferKind};
use super::link::LinkState;

/// Link index of the relaxed <-> strict interconnect.
pub const POOL_LINK: usize = 0;
/// Link index of the device <-> host staging path.
pub const HOST_LINK: usize = 1;

/// Outcome of one chunk completion.
#[derive(Debug)]
pub enum Progress {
    /// Not the link's outstanding chunk (superseded by a cancel reap or a
    /// mis-delivered event): no state changed.
    Stale,
    /// The chunk landed; `orders` are the next chunk(s) to time.
    Advanced { orders: Vec<ChunkOrder> },
    /// The job's final chunk landed; `job` is the completed job and
    /// `orders` the chunk(s) the link started for its successors.
    JobDone {
        job: TransferJob,
        orders: Vec<ChunkOrder>,
    },
}

/// Deterministic multi-link transfer scheduler (see module docs).
#[derive(Debug)]
pub struct TransportEngine {
    links: Vec<LinkState>,
    jobs: HashMap<JobId, TransferJob>,
    /// Active job per request (at most one: a request's KV is a single
    /// cache that is either somewhere or in flight to one place).
    by_req: HashMap<RequestId, JobId>,
    next_job: JobId,
    next_seq: u64,
    /// Chunks per job (`ceil(layers / chunk_layers)`).
    chunks_per_job: usize,
    /// KV bytes per token (all layers) of the served model.
    bytes_per_token: f64,
    /// Fast preemption: stream evicted KV out instead of discarding.
    pub recoverable_eviction: bool,
    /// Host staging buffer available as an eviction destination.
    pub host_staging: bool,
    // ---- global conservation accounting ----
    pub bytes_enqueued: f64,
    pub bytes_delivered: f64,
    pub bytes_cancelled: f64,
    pub jobs_cancelled: u64,
}

impl TransportEngine {
    pub fn new(
        spec: &TransportSpec,
        bytes_per_token: f64,
        layers: usize,
    ) -> Self {
        let chunks_per_job = layers
            .max(1)
            .div_ceil(spec.chunk_layers.max(1))
            .max(1);
        TransportEngine {
            links: vec![
                LinkState::new(spec.pool.clone()),
                LinkState::new(spec.host.clone()),
            ],
            jobs: HashMap::new(),
            by_req: HashMap::new(),
            next_job: 0,
            next_seq: 0,
            chunks_per_job,
            bytes_per_token,
            recoverable_eviction: spec.recoverable_eviction,
            host_staging: spec.host_staging,
            bytes_enqueued: 0.0,
            bytes_delivered: 0.0,
            bytes_cancelled: 0.0,
            jobs_cancelled: 0,
        }
    }

    pub fn chunks_per_job(&self) -> usize {
        self.chunks_per_job
    }

    pub fn links(&self) -> &[LinkState] {
        &self.links
    }

    pub fn active_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Bytes still owed to active (non-cancelled) jobs.
    pub fn in_flight_bytes(&self) -> f64 {
        self.jobs
            .values()
            .filter(|j| !j.cancelled)
            .map(|j| j.remaining_bytes())
            .sum()
    }

    /// The active job moving `req`'s KV, if any.
    pub fn job_of(&self, req: RequestId) -> Option<JobId> {
        self.by_req.get(&req).copied()
    }

    /// Admit a transfer of `kv_tokens` KV tokens for `req`. Returns the job
    /// id plus the chunk order(s) the link issued (empty when the link is
    /// already occupied — the job waits its turn).
    pub fn enqueue(
        &mut self,
        now: f64,
        req: RequestId,
        kind: TransferKind,
        kv_tokens: usize,
    ) -> (JobId, Vec<ChunkOrder>) {
        debug_assert!(
            !self.by_req.contains_key(&req),
            "request {req} already has a transfer in flight"
        );
        let link = kind.link();
        let total_bytes = kv_tokens.max(1) as f64 * self.bytes_per_token;
        let chunks = self.chunks_per_job;
        let id = self.next_job;
        self.next_job += 1;
        self.jobs.insert(
            id,
            TransferJob {
                id,
                req,
                kind,
                link,
                kv_tokens,
                total_bytes,
                chunk_bytes: total_bytes / chunks as f64,
                chunks,
                chunks_done: 0,
                enqueued_at: now,
                cancelled: false,
            },
        );
        self.by_req.insert(req, id);
        self.bytes_enqueued += total_bytes;
        self.links[link].queue.push_back(id);
        (id, self.kick(link))
    }

    /// Start the next chunk on `link` if the medium is free.
    fn kick(&mut self, link: usize) -> Vec<ChunkOrder> {
        if self.links[link].outstanding.is_some() {
            return Vec::new();
        }
        let Some(&job_id) = self.links[link].queue.front() else {
            return Vec::new();
        };
        let (req, chunk, duration) = {
            let job = &self.jobs[&job_id];
            (
                job.req,
                job.chunks_done,
                self.links[link].chunk_duration(job.chunk_bytes),
            )
        };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.links[link].outstanding = Some((job_id, seq, duration));
        vec![ChunkOrder {
            job: job_id,
            req,
            link,
            chunk,
            duration,
            seq,
        }]
    }

    /// A chunk's timed completion fired. Advances the link: credits the
    /// chunk, finishes or rotates the job, reaps cancelled jobs, and starts
    /// the next chunk.
    pub fn on_chunk_done(
        &mut self,
        now: f64,
        job_id: JobId,
        seq: u64,
    ) -> Progress {
        let Some(job) = self.jobs.get(&job_id) else {
            return Progress::Stale;
        };
        let link = job.link;
        match self.links[link].outstanding {
            Some((j, s, _)) if j == job_id && s == seq => {}
            _ => return Progress::Stale,
        }
        let (_, _, duration) = self.links[link].outstanding.take().expect("checked");
        self.links[link].busy_s += duration;
        debug_assert_eq!(self.links[link].queue.front(), Some(&job_id));

        if self.jobs[&job_id].cancelled {
            // Reap: remaining bytes were accounted at cancel time and this
            // chunk's bytes never count as delivered.
            self.links[link].queue.retain(|&j| j != job_id);
            self.jobs.remove(&job_id);
            return Progress::Advanced {
                orders: self.kick(link),
            };
        }

        let (chunk_bytes, done) = {
            let job = self.jobs.get_mut(&job_id).expect("checked");
            job.chunks_done += 1;
            (job.chunk_bytes, job.is_done())
        };
        self.links[link].bytes_moved += chunk_bytes;
        self.bytes_delivered += chunk_bytes;

        if done {
            let job = self.jobs.remove(&job_id).expect("checked");
            self.by_req.remove(&job.req);
            let popped = self.links[link].queue.pop_front();
            debug_assert_eq!(popped, Some(job_id));
            let ideal =
                self.links[link].ideal_duration(job.chunks, job.chunk_bytes);
            self.links[link].stall_s += (now - job.enqueued_at - ideal).max(0.0);
            self.links[link].jobs_completed += 1;
            let orders = self.kick(link);
            Progress::JobDone { job, orders }
        } else {
            if self.links[link].spec.sharing == LinkSharing::FairShare
                && self.links[link].queue.len() > 1
            {
                // Yield the medium to the next active job.
                self.links[link].queue.rotate_left(1);
            }
            Progress::Advanced {
                orders: self.kick(link),
            }
        }
    }

    /// Abort a job mid-flight. Returns the job snapshot exactly once so the
    /// caller can release whatever (KV reservation, staging buffer) it tied
    /// to the job; repeated cancels and cancels of finished jobs return
    /// `None`. A job whose chunk currently occupies the medium is reaped
    /// when that chunk's completion fires (the medium cannot be retracted);
    /// its bytes are accounted as cancelled immediately.
    pub fn cancel(&mut self, job_id: JobId) -> Option<TransferJob> {
        let (req, link, remaining, already) = {
            let job = self.jobs.get(&job_id)?;
            (job.req, job.link, job.remaining_bytes(), job.cancelled)
        };
        if already {
            return None;
        }
        self.by_req.remove(&req);
        self.bytes_cancelled += remaining;
        self.jobs_cancelled += 1;
        let outstanding_here = matches!(
            self.links[link].outstanding,
            Some((j, _, _)) if j == job_id
        );
        if outstanding_here {
            let job = self.jobs.get_mut(&job_id).expect("checked");
            job.cancelled = true;
            Some(job.clone())
        } else {
            self.links[link].queue.retain(|&j| j != job_id);
            self.jobs.remove(&job_id)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareProfile;

    fn engine(sharing: LinkSharing) -> TransportEngine {
        let mut spec =
            TransportSpec::for_hardware(&HardwareProfile::ascend_910c());
        spec.pool.bandwidth = 1000.0;
        spec.pool.latency = 0.0;
        spec.pool.sharing = sharing;
        spec.chunk_layers = 1;
        // 4 bytes per token, 4 layers -> 4 chunks per job.
        TransportEngine::new(&spec, 4.0, 4)
    }

    /// Drive all outstanding orders to completion, returning per-job
    /// completion order.
    fn drain(eng: &mut TransportEngine, mut orders: Vec<ChunkOrder>, t0: f64) -> Vec<JobId> {
        let mut t = t0;
        let mut finished = Vec::new();
        while let Some(o) = orders.pop() {
            t += o.duration;
            match eng.on_chunk_done(t, o.job, o.seq) {
                Progress::Stale => panic!("unexpected stale completion"),
                Progress::Advanced { orders: next } => orders.extend(next),
                Progress::JobDone { job, orders: next } => {
                    finished.push(job.id);
                    orders.extend(next);
                }
            }
        }
        finished
    }

    #[test]
    fn single_job_runs_chunk_by_chunk() {
        let mut eng = engine(LinkSharing::Fifo);
        // 100 tokens * 4 B = 400 B over 4 chunks of 100 B at 1000 B/s.
        let (id, orders) = eng.enqueue(0.0, 7, TransferKind::Dispatch { to_strict: 0 }, 100);
        assert_eq!(orders.len(), 1);
        assert_eq!(orders[0].chunk, 0);
        assert!((orders[0].duration - 0.1).abs() < 1e-12);
        let done = drain(&mut eng, orders, 0.0);
        assert_eq!(done, vec![id]);
        assert_eq!(eng.active_jobs(), 0);
        assert!((eng.bytes_delivered - 400.0).abs() < 1e-9);
        assert!((eng.links()[POOL_LINK].busy_s - 0.4).abs() < 1e-9);
        // Uncontended: no stall.
        assert!(eng.links()[POOL_LINK].stall_s < 1e-9);
    }

    #[test]
    fn fifo_serializes_jobs_in_order() {
        let mut eng = engine(LinkSharing::Fifo);
        let (a, mut orders) =
            eng.enqueue(0.0, 1, TransferKind::Dispatch { to_strict: 0 }, 100);
        let (b, more) =
            eng.enqueue(0.0, 2, TransferKind::Dispatch { to_strict: 0 }, 100);
        assert!(more.is_empty(), "link busy: second job must wait");
        orders.extend(more);
        let done = drain(&mut eng, orders, 0.0);
        assert_eq!(done, vec![a, b]);
        // Job b waited for a: it accrued stall.
        assert!(eng.links()[POOL_LINK].stall_s > 0.3);
    }

    #[test]
    fn fair_share_interleaves_chunks() {
        let mut eng = engine(LinkSharing::FairShare);
        let (a, orders) =
            eng.enqueue(0.0, 1, TransferKind::Dispatch { to_strict: 0 }, 100);
        let (b, _) =
            eng.enqueue(0.0, 2, TransferKind::Dispatch { to_strict: 0 }, 100);
        // Drive to completion recording which job served each chunk.
        let mut t = 0.0;
        let mut served = Vec::new();
        let mut pending = orders;
        let mut finished = Vec::new();
        while let Some(o) = pending.pop() {
            served.push(o.job);
            t += o.duration;
            match eng.on_chunk_done(t, o.job, o.seq) {
                Progress::Stale => panic!("stale"),
                Progress::Advanced { orders } => pending.extend(orders),
                Progress::JobDone { job, orders } => {
                    finished.push(job.id);
                    pending.extend(orders);
                }
            }
        }
        assert_eq!(served, vec![a, b, a, b, a, b, a, b]);
        assert_eq!(finished, vec![a, b]);
    }

    #[test]
    fn cancel_queued_job_is_immediate() {
        let mut eng = engine(LinkSharing::Fifo);
        let (_a, orders) =
            eng.enqueue(0.0, 1, TransferKind::Dispatch { to_strict: 0 }, 100);
        let (b, _) = eng.enqueue(0.0, 2, TransferKind::Offload, 100);
        let (c, _) =
            eng.enqueue(0.0, 3, TransferKind::Dispatch { to_strict: 0 }, 100);
        // c is queued (not outstanding) on the pool link: removed at once.
        let job = eng.cancel(c).expect("first cancel returns the job");
        assert_eq!(job.req, 3);
        assert!(eng.cancel(c).is_none(), "second cancel is a no-op");
        assert_eq!(eng.job_of(3), None);
        // b rides the host link, unaffected.
        assert!(eng.job_of(2).is_some());
        assert_eq!(b, eng.job_of(2).unwrap());
        let done = drain(&mut eng, orders, 0.0);
        assert!(!done.contains(&c));
        assert!(
            (eng.bytes_enqueued
                - eng.bytes_delivered
                - eng.bytes_cancelled
                - eng.in_flight_bytes())
            .abs()
                < 1e-6
        );
    }

    #[test]
    fn cancel_outstanding_job_reaps_on_completion() {
        let mut eng = engine(LinkSharing::Fifo);
        let (a, orders) =
            eng.enqueue(0.0, 1, TransferKind::Dispatch { to_strict: 0 }, 100);
        let (b, _) =
            eng.enqueue(0.0, 2, TransferKind::Dispatch { to_strict: 0 }, 100);
        assert!(eng.cancel(a).is_some());
        assert!(eng.cancel(a).is_none(), "no double free");
        // a's in-flight chunk still completes; it frees the link for b.
        let o = orders[0];
        let next = match eng.on_chunk_done(o.duration, o.job, o.seq) {
            Progress::Advanced { orders } => orders,
            p => panic!("cancelled job must not complete: {p:?}"),
        };
        assert_eq!(next.len(), 1);
        assert_eq!(next[0].job, b);
        // a is fully gone; a stale re-delivery of its chunk is ignored.
        assert!(matches!(
            eng.on_chunk_done(1.0, o.job, o.seq),
            Progress::Stale
        ));
        assert_eq!(eng.active_jobs(), 1);
        assert!((eng.bytes_cancelled - 400.0).abs() < 1e-9);
    }

    #[test]
    fn stale_seq_is_ignored() {
        let mut eng = engine(LinkSharing::Fifo);
        let (a, orders) =
            eng.enqueue(0.0, 1, TransferKind::Dispatch { to_strict: 0 }, 100);
        assert!(matches!(
            eng.on_chunk_done(0.1, a, orders[0].seq + 999),
            Progress::Stale
        ));
        // The real completion still works afterwards.
        assert!(matches!(
            eng.on_chunk_done(0.1, a, orders[0].seq),
            Progress::Advanced { .. }
        ));
    }

    #[test]
    fn chunk_plan_follows_config() {
        let mut spec =
            TransportSpec::for_hardware(&HardwareProfile::ascend_910c());
        spec.chunk_layers = 7;
        let eng = TransportEngine::new(&spec, 2.0, 28);
        assert_eq!(eng.chunks_per_job(), 4);
        let eng = TransportEngine::new(&spec, 2.0, 4);
        assert_eq!(eng.chunks_per_job(), 1);
    }
}
