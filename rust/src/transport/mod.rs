//! KV transport subsystem — every byte of KV cache that moves between
//! instances (or to/from host staging) flows through here.
//!
//! Before this subsystem, inter-instance KV movement was a single scalar
//! `PerfModel::kv_transfer_latency(kv_len)`: transfers never contended for
//! the interconnect, never overlapped observably with decode steps, and the
//! engine moved KV instantaneously (the DESIGN.md §3 divergence). This
//! module replaces that with a modeled interconnect:
//!
//! - [`link`] — per-link state over the [`crate::config::LinkSpec`]
//!   topology: one chunk in flight per link, FIFO or fair-share job
//!   scheduling, byte/busy/stall accounting;
//! - [`job`] — [`TransferJob`]s: chunked layer-wise transfers
//!   ([`TransferKind`] names the five KV movements of the system — decode
//!   dispatch, Algorithm 1 migration, and the recoverable fast-preemption
//!   triple rescue/offload/restore);
//! - [`engine`] — [`TransportEngine`]: the deterministic queueing engine.
//!   `enqueue` admits a job and returns the chunk work orders the executor
//!   must time; `on_chunk_done` advances the link and yields follow-up
//!   orders or the completed job; `cancel` aborts a job mid-flight with
//!   exactly-once resource release.
//!
//! The engine lives *inside* [`crate::scheduler::SchedulerCore`], so its
//! decisions are part of the substrate-independent action stream: both the
//! virtual executors and the real engine drive identical chunk orders
//! (asserted by `tests/scheduler_differential.rs`), and the real engine
//! copies KV host vectors chunk-by-chunk on those orders. Conservation
//! invariants (bytes delivered == bytes enqueued, monotone per-link
//! completions, exactly-once cancel) are property-tested in
//! `tests/transport_properties.rs`.

pub mod engine;
pub mod job;
pub mod link;

pub use self::engine::{Progress, TransportEngine, HOST_LINK, POOL_LINK};
pub use self::job::{ChunkOrder, JobId, TransferJob, TransferKind};
pub use self::link::LinkState;
