//! Runtime state of one interconnect link: the serialized medium behind a
//! [`crate::config::LinkSpec`]. One chunk occupies the link at a time;
//! which job's chunk goes next is the sharing discipline's choice (FIFO
//! serves the head job to completion, fair-share round-robins at chunk
//! granularity).

use std::collections::VecDeque;

use crate::config::LinkSpec;

use super::job::JobId;

/// Mutable per-link queueing and accounting state.
#[derive(Debug)]
pub struct LinkState {
    pub spec: LinkSpec,
    /// Active jobs on this link, head = next to be served.
    pub queue: VecDeque<JobId>,
    /// The chunk currently occupying the medium: (job, seq, duration).
    pub outstanding: Option<(JobId, u64, f64)>,
    // ---- accounting ----
    /// Seconds the medium spent serving chunks.
    pub busy_s: f64,
    /// Bytes of completed (non-cancelled) chunks.
    pub bytes_moved: f64,
    pub jobs_completed: u64,
    /// Sum over completed jobs of (actual - ideal) transfer time: the
    /// queueing/contention delay the link added.
    pub stall_s: f64,
}

impl LinkState {
    pub fn new(spec: LinkSpec) -> Self {
        LinkState {
            spec,
            queue: VecDeque::new(),
            outstanding: None,
            busy_s: 0.0,
            bytes_moved: 0.0,
            jobs_completed: 0,
            stall_s: 0.0,
        }
    }

    /// Service time of one `bytes`-sized chunk on an idle medium.
    pub fn chunk_duration(&self, bytes: f64) -> f64 {
        self.spec.latency + bytes / self.spec.bandwidth.max(1.0)
    }

    /// Contention-free duration of a whole job (`chunks` chunks of
    /// `chunk_bytes`): the baseline for stall accounting.
    pub fn ideal_duration(&self, chunks: usize, chunk_bytes: f64) -> f64 {
        chunks as f64 * self.chunk_duration(chunk_bytes)
    }

    /// Busy fraction over an observation window.
    pub fn utilization(&self, window_s: f64) -> f64 {
        if window_s <= 0.0 {
            0.0
        } else {
            self.busy_s / window_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkSharing;

    fn link() -> LinkState {
        LinkState::new(LinkSpec {
            name: "test".into(),
            bandwidth: 100.0,
            latency: 0.5,
            sharing: LinkSharing::Fifo,
        })
    }

    #[test]
    fn durations() {
        let l = link();
        assert!((l.chunk_duration(100.0) - 1.5).abs() < 1e-12);
        assert!((l.ideal_duration(4, 50.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_window() {
        let mut l = link();
        l.busy_s = 5.0;
        assert!((l.utilization(10.0) - 0.5).abs() < 1e-12);
        assert_eq!(l.utilization(0.0), 0.0);
    }
}
