//! Discrete-event cluster simulator — a thin compatibility shim over the
//! unified scheduling subsystem ([`crate::scheduler`]).
//!
//! The §3.4 decision loop (Algorithms 1–2, preemption, gating) lives in
//! [`crate::scheduler::SchedulerCore`]; this module pairs it with a
//! [`crate::scheduler::VirtualExecutor`] that replays a workload trace on a
//! virtual clock with iteration latencies from the roofline performance
//! model — the substrate substitution for the paper's 910c testbed
//! (DESIGN.md §2). Because OOCO's own scheduler acts on perf-model
//! predictions, the simulator exercises *exactly* the same decision code
//! the real engine runs; only the clock is virtual.
//!
//! Event types: request arrival, relaxed-instance step end, strict-instance
//! step end, KV-transfer completion. Scheduling decisions happen at step
//! boundaries (iteration-level granularity, §2.1), including preemption
//! (layer-level truncation of a running offline prefill) and eviction
//! (which only takes effect between iterations, as in real engines).

pub use crate::scheduler::{Event, EventKind, EventQueue, QueueKind};

use crate::config::ServingConfig;
use crate::coordinator::{Ablation, OverloadMode, Policy};
use crate::metrics::{
    ChunkReport, PoolReport, PrefixReport, Recorder, Report,
    TransportReport,
};
use crate::obs::{self, ProfileReport, Subsystem};
use crate::scheduler::{CoreConfig, Executor, SchedulerCore, VirtualExecutor};
use crate::telemetry::{TelemetryOpts, TelemetryOut, TraceRecorder};
use crate::trace::Trace;
use crate::util::json::Json;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub serving: ServingConfig,
    pub policy: Policy,
    pub ablation: Ablation,
    /// §3.4.4 behaviour when the online-only batch exceeds the SLO bound.
    pub overload_mode: OverloadMode,
    /// Extra simulated time after the last arrival to drain in-flight work.
    pub drain_s: f64,
    /// KV page size in tokens.
    pub block_tokens: usize,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(serving: ServingConfig, policy: Policy) -> Self {
        SimConfig {
            serving,
            policy,
            ablation: Ablation::full(),
            overload_mode: OverloadMode::BestEffort,
            drain_s: 300.0,
            block_tokens: 16,
            seed: 0,
        }
    }

    /// The substrate-independent slice of this configuration.
    pub fn core(&self) -> CoreConfig {
        CoreConfig {
            serving: self.serving.clone(),
            policy: self.policy,
            ablation: self.ablation,
            overload_mode: self.overload_mode,
            block_tokens: self.block_tokens,
            seed: self.seed,
        }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub report: Report,
    /// Simulated end time.
    pub end_time: f64,
    /// Busy fraction of strict instances over the trace window.
    pub strict_utilization: f64,
    /// Busy fraction of relaxed instances.
    pub relaxed_utilization: f64,
    /// Total strict decode iterations executed.
    pub strict_steps: u64,
    /// Offline tokens decoded on strict instances (mix-in volume).
    pub strict_offline_tokens: u64,
    /// Total preemption events (online truncating offline prefill).
    pub preemptions: u64,
    /// Total offline evictions (strict + relaxed).
    pub evictions: u64,
    /// Total offline requests migrated relaxed -> strict.
    pub migrations: u64,
    /// Strict evictions recovered by streaming KV out (fast preemption).
    pub rescues: u64,
    /// Evictions recovered via the host staging buffer.
    pub offloads: u64,
    /// KV-transport link accounting (contention, stall, recovery stats).
    pub transport: TransportReport,
    /// Elastic pool-manager accounting (plans, flips, stranded capacity).
    pub pool: PoolReport,
    /// Prefix-sharing cache accounting (hit rate, prefill tokens saved,
    /// reclaimable capacity — DESIGN.md §3.7).
    pub prefix: PrefixReport,
    /// Chunked-prefill iteration accounting (budget utilization,
    /// interference delay, preemption work retained — DESIGN.md §3.8).
    pub chunk: ChunkReport,
    /// Flight-recorder output (timeline, attribution, optional Perfetto
    /// trace — DESIGN.md §3.10). `None` unless the run was traced via
    /// [`simulate_traced`].
    pub telemetry: Option<TelemetryOut>,
    /// Loop events delivered to the core (arrivals, step ends, chunks).
    pub events: u64,
    /// Self-profiler breakdown (DESIGN.md §3.11). `None` unless the run
    /// was profiled via [`simulate_observed`].
    pub profile: Option<ProfileReport>,
}

/// Run the simulation of `trace` under `cfg`: build a [`SchedulerCore`],
/// drive it with a [`VirtualExecutor`], and aggregate the outcome.
pub fn simulate(trace: &Trace, cfg: &SimConfig) -> SimResult {
    simulate_traced(trace, cfg, None)
}

/// [`simulate`] with an optional flight recorder attached to the
/// executor's action stream; its output lands in
/// [`SimResult::telemetry`].
pub fn simulate_traced(
    trace: &Trace,
    cfg: &SimConfig,
    telemetry: Option<TelemetryOpts>,
) -> SimResult {
    simulate_observed(trace, cfg, telemetry, false)
}

/// [`simulate_traced`] with the self-profiler optionally armed
/// (DESIGN.md §3.11). The probes are pure observers — they read clocks
/// but never simulation state — so `profile: true` leaves every
/// deterministic field of the result byte-identical to an unprofiled
/// same-seed run (`tests/obs_properties.rs` pins this); the breakdown
/// lands in [`SimResult::profile`].
pub fn simulate_observed(
    trace: &Trace,
    cfg: &SimConfig,
    telemetry: Option<TelemetryOpts>,
    profile: bool,
) -> SimResult {
    simulate_queued(trace, cfg, telemetry, profile, QueueKind::Calendar)
}

/// [`simulate_observed`] on an explicit time-queue implementation. Both
/// kinds honor the identical (time, insertion-order) contract, so every
/// deterministic output field is byte-identical across them — pinned by
/// `tests/queue_differential.rs`.
pub fn simulate_queued(
    trace: &Trace,
    cfg: &SimConfig,
    telemetry: Option<TelemetryOpts>,
    profile: bool,
    queue_kind: QueueKind,
) -> SimResult {
    if profile {
        obs::enable();
    }
    let horizon = trace.duration() + cfg.drain_s;
    let (mut core, mut executor) = {
        let _p = obs::scope(Subsystem::Setup);
        (
            SchedulerCore::new(trace.requests.clone(), cfg.core()),
            VirtualExecutor::with_queue(trace, horizon, queue_kind),
        )
    };
    if let Some(opts) = telemetry {
        let mut rec = TraceRecorder::flight(opts);
        rec.set_horizon(horizon);
        if let Some(wp) = opts.watch {
            // Armed before registration so the watchdog sees the same
            // workload statics and topology the recorder does.
            rec.arm_watch(crate::watch::Watchdog::new(wp, &cfg.serving));
        }
        rec.register_requests(&trace.requests);
        rec.register_replica(
            0,
            core.cluster.relaxed.len(),
            core.cluster.strict.len(),
        );
        executor.telemetry = rec;
    }
    let stats = executor
        .run(&mut core)
        .expect("virtual execution is infallible");
    let mut result = {
        let _p = obs::scope(Subsystem::Metrics);
        build_result(&core, trace, cfg, stats.end_time)
    };
    result.events = stats.events;
    if executor.telemetry.is_enabled() {
        for r in &core.cluster.requests {
            executor.telemetry.finalize_request(r);
        }
        result.telemetry = executor.telemetry.finish(stats.end_time);
    }
    if profile {
        result.profile = Some(obs::take_report());
    }
    result
}

/// Compose the machine-readable `--json-out` object for a single-cluster
/// run: config echo, report sections, optional telemetry, optional
/// profile. The CLI layers the `meta` header on top; everything except
/// `profile` is deterministic for a fixed seed.
pub fn result_json(cfg: &SimConfig, res: &SimResult) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("policy", Json::Str(cfg.policy.to_string())),
        ("pool_policy", Json::Str(cfg.serving.pool.to_string())),
        (
            "chunk_tokens",
            Json::Str(cfg.serving.chunk_tokens.to_string()),
        ),
        ("seed", Json::Num(cfg.seed as f64)),
        ("events", Json::Num(res.events as f64)),
        ("report", res.report.to_json()),
        ("transport", res.transport.to_json()),
        ("pool", res.pool.to_json()),
        ("prefix", res.prefix.to_json()),
        ("chunk", res.chunk.to_json()),
    ];
    if let Some(tel) = &res.telemetry {
        pairs.push(("timeline", tel.timeline.clone()));
        pairs.push(("attribution", tel.attribution.clone()));
        if let Some(inc) = &tel.incidents {
            pairs.push(("incidents", inc.clone()));
        }
    }
    if let Some(profile) = &res.profile {
        pairs.push(("profile", profile.to_json()));
    }
    Json::obj(pairs)
}

fn build_result(
    core: &SchedulerCore,
    trace: &Trace,
    cfg: &SimConfig,
    end_time: f64,
) -> SimResult {
    let cluster = &core.cluster;
    let mut recorder = Recorder::new(&cfg.serving.slo);
    for r in &cluster.requests {
        recorder.record(r);
    }
    let duration = trace.duration().max(1e-9);
    let report = recorder.report(duration);
    // Utilization denominators are per-role instance-seconds: under
    // elastic repartitioning pool sizes change mid-run, so `duration ×
    // final size` would misattribute. The window runs to the end of the
    // drain — the same one `transport_report` uses — because busy_s (and
    // post-arrival flips) accrue until then.
    let (relaxed_inst_s, strict_inst_s) =
        cluster.role_instance_seconds(end_time.max(duration));
    SimResult {
        report,
        end_time,
        strict_utilization: cluster.strict_busy_s() / strict_inst_s.max(1e-9),
        relaxed_utilization: cluster.relaxed_busy_s()
            / relaxed_inst_s.max(1e-9),
        strict_steps: cluster.strict_steps(),
        strict_offline_tokens: cluster.strict_offline_tokens(),
        preemptions: cluster.preemptions,
        evictions: cluster.evictions,
        migrations: cluster.migrations,
        rescues: cluster.rescues,
        offloads: cluster.offloads,
        transport: core.transport_report(end_time.max(duration)),
        pool: core.pool_report(),
        prefix: core.prefix_report(),
        chunk: core.chunk_report(),
        telemetry: None,
        events: 0,
        profile: None,
    }
}
