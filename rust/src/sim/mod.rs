//! Discrete-event cluster simulator.
//!
//! Drives the coordinator's scheduling logic (Algorithms 1–2, preemption,
//! gating) over a workload trace with iteration latencies from the roofline
//! performance model — the substrate substitution for the paper's 910c
//! testbed (DESIGN.md §2). Because OOCO's own scheduler acts on perf-model
//! predictions, the simulator exercises *exactly* the same decision code
//! the real engine runs; only the clock is virtual.
//!
//! Event types: request arrival, relaxed-instance step end, strict-instance
//! step end, KV-transfer completion. Scheduling decisions happen at step
//! boundaries (iteration-level granularity, §2.1), including preemption
//! (layer-level truncation of a running offline prefill) and eviction
//! (which only takes effect between iterations, as in real engines).

mod events;

pub use events::{Event, EventKind, EventQueue};

use std::collections::VecDeque;

use crate::config::ServingConfig;
use crate::coordinator::{
    migration_decision, pick_migration_candidates, preemption_delay,
    select_decode_batch, select_decode_batch_capped, select_evictions,
    shed_online_overload, Ablation, Candidate, LengthPref, OverloadMode,
    Policy, Router,
};
use crate::instance::{RelaxedInstance, Step, StepKind, StrictInstance};
use crate::metrics::{Recorder, Report};
use crate::perfmodel::{BatchStats, PerfModel};
use crate::request::{Class, Phase, Request, RequestId};
use crate::trace::Trace;
use crate::util::rng::Pcg;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub serving: ServingConfig,
    pub policy: Policy,
    pub ablation: Ablation,
    /// §3.4.4 behaviour when the online-only batch exceeds the SLO bound.
    pub overload_mode: OverloadMode,
    /// Extra simulated time after the last arrival to drain in-flight work.
    pub drain_s: f64,
    /// KV page size in tokens.
    pub block_tokens: usize,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(serving: ServingConfig, policy: Policy) -> Self {
        SimConfig {
            serving,
            policy,
            ablation: Ablation::full(),
            overload_mode: OverloadMode::BestEffort,
            drain_s: 300.0,
            block_tokens: 16,
            seed: 0,
        }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub report: Report,
    /// Simulated end time.
    pub end_time: f64,
    /// Busy fraction of strict instances over the trace window.
    pub strict_utilization: f64,
    /// Busy fraction of relaxed instances.
    pub relaxed_utilization: f64,
    /// Total strict decode iterations executed.
    pub strict_steps: u64,
    /// Offline tokens decoded on strict instances (mix-in volume).
    pub strict_offline_tokens: u64,
    /// Total preemption events (online truncating offline prefill).
    pub preemptions: u64,
    /// Total offline evictions (strict + relaxed).
    pub evictions: u64,
    /// Total offline requests migrated relaxed -> strict.
    pub migrations: u64,
}

/// Where a not-yet-decoding request's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq)]
enum KvHome {
    None,
    Relaxed(usize),
    Strict(usize),
}

/// Run the simulation of `trace` under `cfg`.
pub fn simulate(trace: &Trace, cfg: &SimConfig) -> SimResult {
    Sim::new(trace, cfg).run()
}

struct Sim<'a> {
    cfg: &'a SimConfig,
    pm: PerfModel,
    requests: Vec<Request>,
    kv_home: Vec<KvHome>,
    relaxed: Vec<RelaxedInstance>,
    strict: Vec<StrictInstance>,
    /// Offline requests waiting for (re-)prefill, shared across the pool.
    offline_backlog: VecDeque<RequestId>,
    router: Router,
    queue: EventQueue,
    now: f64,
    trace_end: f64,
    horizon: f64,
    rng: Pcg,
    /// Per-strict-instance (batch stats, all-included) of the running step,
    /// consumed by the Algorithm 1 decision at the step boundary.
    strict_step_meta: Vec<Option<(BatchStats, bool)>>,
    // counters
    preemptions: u64,
    evictions: u64,
    migrations: u64,
}

impl<'a> Sim<'a> {
    fn new(trace: &Trace, cfg: &'a SimConfig) -> Self {
        let pm = PerfModel::new(
            cfg.serving.model.clone(),
            cfg.serving.hardware.clone(),
        );
        let cap = pm.max_kv_tokens().max(cfg.block_tokens);
        let n_relaxed = cfg.serving.cluster.relaxed_instances.max(1);
        let n_strict = cfg.serving.cluster.strict_instances.max(1);
        let relaxed = (0..n_relaxed)
            .map(|i| RelaxedInstance::new(i, cap, cfg.block_tokens))
            .collect();
        let strict = (0..n_strict)
            .map(|i| StrictInstance::new(i, cap, cfg.block_tokens))
            .collect();

        let mut queue = EventQueue::new();
        for r in &trace.requests {
            queue.push(r.arrival, EventKind::Arrival(r.id));
        }
        let trace_end = trace.duration();
        Sim {
            cfg,
            pm,
            kv_home: vec![KvHome::None; trace.requests.len()],
            requests: trace.requests.clone(),
            relaxed,
            strict,
            offline_backlog: VecDeque::new(),
            router: Router::new(n_relaxed, n_strict),
            queue,
            now: 0.0,
            trace_end,
            horizon: trace_end + cfg.drain_s,
            rng: Pcg::new(cfg.seed, 9090),
            strict_step_meta: vec![None; n_strict],
            preemptions: 0,
            evictions: 0,
            migrations: 0,
        }
    }

    // ------------------------------------------------------------ main loop

    fn run(mut self) -> SimResult {
        while let Some(ev) = self.queue.pop() {
            if ev.time > self.horizon {
                break;
            }
            self.now = ev.time;
            match ev.kind {
                EventKind::Arrival(rid) => self.on_arrival(rid),
                EventKind::RelaxedStep { inst, seq } => {
                    self.on_relaxed_step_end(inst, seq)
                }
                EventKind::StrictStep { inst, seq } => {
                    self.on_strict_step_end(inst, seq)
                }
                EventKind::TransferDone { req, strict } => {
                    self.on_transfer_done(req, strict)
                }
            }
        }
        self.build_result()
    }

    // ------------------------------------------------------------- arrivals

    /// Is this request scheduled as "online" by the active policy?
    /// (`base P/D` treats offline requests as ordinary online requests.)
    fn scheduled_online(&self, rid: RequestId) -> bool {
        self.requests[rid as usize].class.is_online()
            || self.cfg.policy == Policy::BasePd
    }

    fn on_arrival(&mut self, rid: RequestId) {
        if self.scheduled_online(rid) {
            let prompt = self.requests[rid as usize].prompt_len;
            let inst = self.router.route_prefill(prompt);
            self.relaxed[inst].online_queue.push_back(rid);
            self.maybe_preempt(inst);
            if self.relaxed[inst].is_idle() {
                self.start_relaxed_step(inst);
            }
        } else {
            self.offline_backlog.push_back(rid);
            self.kick_idle_relaxed();
        }
    }

    /// Truncate a running offline prefill at the next layer boundary
    /// (§3.4.1 layer-level interruption).
    fn maybe_preempt(&mut self, inst: usize) {
        if !self.cfg.policy.preempts_offline_prefill() {
            return;
        }
        let now = self.now;
        let inst_ref = &mut self.relaxed[inst];
        let Some(step) = inst_ref.step.as_mut() else {
            return;
        };
        if step.kind != StepKind::PrefillOffline || step.preempted {
            return;
        }
        let span = (step.ends - step.started).max(1e-9);
        let elapsed_frac = ((now - step.started) / span).clamp(0.0, 1.0);
        let mean_prompt = (step
            .participants
            .iter()
            .map(|&r| self.requests[r as usize].recompute_len())
            .sum::<usize>()
            / step.participants.len().max(1))
        .max(1);
        let delay = preemption_delay(&self.pm, mean_prompt, elapsed_frac);
        let new_end = now + delay;
        if new_end < step.ends {
            step.ends = new_end;
            step.preempted = true;
            step.seq = {
                // can't call alloc_seq while holding step borrow
                let seq = inst_ref.next_seq + 1;
                seq
            };
            inst_ref.next_seq += 1;
            let seq = inst_ref.next_seq;
            self.queue
                .push(new_end, EventKind::RelaxedStep { inst, seq });
            self.preemptions += 1;
        }
    }

    fn kick_idle_relaxed(&mut self) {
        for i in 0..self.relaxed.len() {
            if self.relaxed[i].is_idle() {
                self.start_relaxed_step(i);
                if !self.relaxed[i].is_idle() {
                    return;
                }
            }
        }
    }

    // ----------------------------------------------------- relaxed stepping

    fn start_relaxed_step(&mut self, inst: usize) {
        if !self.relaxed[inst].is_idle() {
            return;
        }
        if self.start_online_prefill(inst) {
            return;
        }
        if self.start_offline_prefill(inst) {
            return;
        }
        self.start_relaxed_decode(inst);
    }

    /// Batch online prefills up to the token budget.
    fn start_online_prefill(&mut self, inst: usize) -> bool {
        if self.relaxed[inst].online_queue.is_empty() {
            return false;
        }
        let budget = self.cfg.serving.sched.prefill_token_budget;
        let mut batch: Vec<RequestId> = Vec::new();
        let mut lens: Vec<usize> = Vec::new();
        let mut used = 0usize;
        while let Some(&rid) = self.relaxed[inst].online_queue.front() {
            let len = self.requests[rid as usize].recompute_len();
            if !batch.is_empty() && used + len > budget {
                break;
            }
            // KV space for the prefill output, evicting offline if needed.
            if !self.fit_on_relaxed(inst, rid, len + 1) {
                if batch.is_empty() {
                    // Head request cannot fit even after eviction: reject.
                    self.relaxed[inst].online_queue.pop_front();
                    self.requests[rid as usize].phase = Phase::Finished;
                    continue;
                }
                break;
            }
            self.relaxed[inst].online_queue.pop_front();
            self.relaxed[inst].kv.admit(rid, len + 1).expect("fit checked");
            self.kv_home[rid as usize] = KvHome::Relaxed(inst);
            self.requests[rid as usize].phase = Phase::Prefilling;
            used += len;
            batch.push(rid);
            lens.push(len);
        }
        if batch.is_empty() {
            return false;
        }
        let latency = self.pm.prefill_cost(&lens).latency_s;
        self.begin_step(inst, StepKind::PrefillOnline, batch, latency);
        self.relaxed[inst].busy_online_prefill_s += latency;
        true
    }

    /// Make room for `tokens` on a relaxed instance by evicting offline
    /// decode residents (oldest first — relaxed nodes have no bottleneck
    /// preference; their decode batch has no SLO).
    fn fit_on_relaxed(&mut self, inst: usize, _for_rid: RequestId, tokens: usize) -> bool {
        while !self.relaxed[inst].kv.can_fit(tokens) {
            // Evict a parked/decoding offline resident not in the current
            // step (relaxed instance is idle here, so all are safe).
            let Some(&victim) = self.relaxed[inst].offline_decoding.first() else {
                return false;
            };
            self.evict_offline_from_relaxed(inst, victim);
        }
        true
    }

    fn evict_offline_from_relaxed(&mut self, inst: usize, rid: RequestId) {
        self.relaxed[inst].kv.release(rid).expect("resident kv");
        self.relaxed[inst].offline_decoding.retain(|&r| r != rid);
        self.kv_home[rid as usize] = KvHome::None;
        self.requests[rid as usize].evict();
        self.offline_backlog.push_back(rid);
        self.evictions += 1;
    }

    /// Admit offline prefills from the global backlog (gating in OOCO,
    /// plain idle-only admission in `online priority`).
    fn start_offline_prefill(&mut self, inst: usize) -> bool {
        if self.offline_backlog.is_empty() {
            return false;
        }
        // base P/D never reaches here (offline went through the online path).
        let budget = self.cfg.serving.sched.prefill_token_budget;
        let gating_on =
            self.cfg.policy.gating_enabled() && self.cfg.ablation.gating;
        let mut batch = Vec::new();
        let mut lens = Vec::new();
        let mut used = 0usize;
        // Reserve headroom for a typical online prefill so offline work
        // doesn't crowd out preempting arrivals.
        let reserve = 4096usize;
        while let Some(&rid) = self.offline_backlog.front() {
            let len = self.requests[rid as usize].recompute_len();
            if !batch.is_empty() && used + len > budget {
                break;
            }
            let free = self.relaxed[inst].kv.free_tokens();
            if free < len + 1 + reserve {
                break;
            }
            if gating_on && !self.gating_admits(inst, rid, free - reserve) {
                break;
            }
            self.offline_backlog.pop_front();
            self.relaxed[inst].kv.admit(rid, len + 1).expect("fit checked");
            self.kv_home[rid as usize] = KvHome::Relaxed(inst);
            self.requests[rid as usize].phase = Phase::Prefilling;
            used += len;
            batch.push(rid);
            lens.push(len);
        }
        if batch.is_empty() {
            return false;
        }
        let latency = self.pm.prefill_cost(&lens).latency_s;
        self.begin_step(inst, StepKind::PrefillOffline, batch, latency);
        true
    }

    fn gating_admits(&mut self, inst: usize, rid: RequestId, free: usize) -> bool {
        let pool = self.relaxed_pool_stats(inst);
        let req = &self.requests[rid as usize];
        let remaining: f64 = if self.relaxed[inst].offline_decoding.is_empty() {
            0.0
        } else {
            self.relaxed[inst]
                .offline_decoding
                .iter()
                .map(|&r| {
                    let q = &self.requests[r as usize];
                    (q.output_len - q.generated.min(q.output_len)) as f64
                })
                .sum::<f64>()
                / self.relaxed[inst].offline_decoding.len() as f64
        };
        let input = crate::coordinator::GatingInput {
            pool,
            candidate_prompt: req.recompute_len(),
            candidate_output: req.output_len,
            pool_mean_remaining: remaining,
            free_kv_tokens: free,
        };
        crate::coordinator::should_prefill_offline(
            &self.pm,
            &input,
            &self.cfg.serving.sched,
        )
    }

    fn relaxed_pool_stats(&self, inst: usize) -> BatchStats {
        let mut s = BatchStats::empty();
        for &r in &self.relaxed[inst].offline_decoding {
            s = s.with(self.requests[r as usize].kv_len());
        }
        s
    }

    /// Offline decode on a relaxed instance (OOCO's latency-constraint
    /// flexibility): batch every resident — no per-iteration bound here.
    fn start_relaxed_decode(&mut self, inst: usize) {
        if !self.cfg.policy.offline_decode_on_relaxed()
            || self.relaxed[inst].offline_decoding.is_empty()
        {
            return;
        }
        let batch: Vec<RequestId> = self.relaxed[inst].offline_decoding.clone();
        let stats = self.relaxed_pool_stats(inst);
        let latency = self.pm.decode_latency(stats);
        self.begin_step(inst, StepKind::DecodeRelaxed, batch, latency);
    }

    fn begin_step(
        &mut self,
        inst: usize,
        kind: StepKind,
        participants: Vec<RequestId>,
        latency: f64,
    ) {
        let seq = self.relaxed[inst].alloc_seq();
        let ends = self.now + latency.max(1e-9);
        self.relaxed[inst].step = Some(Step {
            kind,
            started: self.now,
            ends,
            participants,
            seq,
            preempted: false,
        });
        self.relaxed[inst].busy_s += latency;
        self.queue.push(ends, EventKind::RelaxedStep { inst, seq });
    }

    fn on_relaxed_step_end(&mut self, inst: usize, seq: u64) {
        let valid = self.relaxed[inst]
            .step
            .as_ref()
            .map(|s| s.seq == seq)
            .unwrap_or(false);
        if !valid {
            return; // stale completion after preemption reschedule
        }
        let step = self.relaxed[inst].step.take().expect("checked");
        match step.kind {
            StepKind::PrefillOnline => {
                for &rid in &step.participants {
                    self.finish_prefill_online(inst, rid);
                }
            }
            StepKind::PrefillOffline => {
                if step.preempted {
                    // Layer-level interruption: work discarded, requests
                    // return to the backlog for recompute.
                    for &rid in &step.participants {
                        self.relaxed[inst].kv.release(rid).expect("kv");
                        self.kv_home[rid as usize] = KvHome::None;
                        self.requests[rid as usize].phase = Phase::Queued;
                        self.offline_backlog.push_front(rid);
                    }
                } else {
                    for &rid in &step.participants {
                        self.finish_prefill_offline(inst, rid);
                    }
                }
            }
            StepKind::DecodeRelaxed => {
                for &rid in &step.participants {
                    self.relaxed_decode_token(inst, rid);
                }
            }
            StepKind::DecodeStrict => unreachable!("strict step on relaxed"),
        }
        self.start_relaxed_step(inst);
    }

    fn finish_prefill_online(&mut self, inst: usize, rid: RequestId) {
        self.router
            .prefill_done(inst, self.requests[rid as usize].recompute_len());
        self.requests[rid as usize].mark_first_token(self.now);
        if self.requests[rid as usize].is_finished() {
            // Single-token request: done at prefill.
            self.requests[rid as usize].finished_at = Some(self.now);
            self.requests[rid as usize].phase = Phase::Finished;
            self.relaxed[inst].kv.release(rid).expect("kv");
            self.kv_home[rid as usize] = KvHome::None;
            return;
        }
        // Push model: dispatch to a strict instance immediately.
        let target = self.router.route_decode(self.requests[rid as usize].kv_len());
        self.try_dispatch_to_strict(rid, inst, target);
    }

    /// Reserve KV on the strict instance (evicting offline per policy) and
    /// start the transfer; park in `waiting_for_space` on failure.
    fn try_dispatch_to_strict(&mut self, rid: RequestId, from_relaxed: usize, target: usize) {
        let kv_len = self.requests[rid as usize].kv_len();
        let need = kv_len + 1;
        if !self.strict[target].kv.can_fit(need) {
            self.make_room_on_strict(target, need);
        }
        if self.strict[target].kv.can_fit(need) {
            self.strict[target].kv.admit(rid, need).expect("fit checked");
            self.relaxed[from_relaxed].kv.release(rid).expect("kv");
            self.kv_home[rid as usize] = KvHome::Strict(target);
            self.requests[rid as usize].phase = Phase::Migrating;
            self.strict[target].inbound.push(rid);
            let delay = self.pm.kv_transfer_latency(kv_len);
            self.queue.push(
                self.now + delay,
                EventKind::TransferDone {
                    req: rid,
                    strict: target,
                },
            );
        } else {
            // Overload: wait (KV stays on the relaxed node).
            self.strict[target].waiting_for_space.push_back(rid);
        }
    }

    /// Evict offline decode residents on a strict instance to free `need`
    /// tokens. Only legal between steps; callers run at step boundaries.
    fn make_room_on_strict(&mut self, inst: usize, need: usize) {
        if self.strict[inst].offline.is_empty() {
            return;
        }
        // Never evict requests participating in a running step.
        let in_flight: Vec<RequestId> = self.strict[inst]
            .step
            .as_ref()
            .map(|s| s.participants.clone())
            .unwrap_or_default();
        let victims: Vec<Candidate> = self.strict[inst]
            .offline
            .iter()
            .filter(|r| !in_flight.contains(r))
            .map(|&r| (r, self.requests[r as usize].kv_len()))
            .collect();
        if victims.is_empty() {
            return;
        }
        let free_now = self.strict[inst].kv.free_tokens();
        let deficit = need.saturating_sub(free_now);
        if deficit == 0 {
            return;
        }
        let stats = self.strict_resident_stats(inst);
        let bottleneck = self.pm.decode_bottleneck(stats);
        let aware = self.cfg.policy.bottleneck_aware_eviction()
            && self.cfg.ablation.bottleneck_eviction;
        let chosen =
            select_evictions(&self.pm, &victims, deficit, bottleneck, aware);
        for rid in chosen {
            self.evict_offline_from_strict(inst, rid);
        }
    }

    fn evict_offline_from_strict(&mut self, inst: usize, rid: RequestId) {
        let kv = self.requests[rid as usize].kv_len();
        self.strict[inst].kv.release(rid).expect("resident");
        self.strict[inst].remove_offline(rid);
        self.router.decode_done(inst, kv);
        self.kv_home[rid as usize] = KvHome::None;
        self.requests[rid as usize].evict();
        self.offline_backlog.push_back(rid);
        self.evictions += 1;
        self.kick_idle_relaxed();
    }

    fn finish_prefill_offline(&mut self, inst: usize, rid: RequestId) {
        self.requests[rid as usize].mark_first_token(self.now);
        if self.requests[rid as usize].is_finished() {
            self.requests[rid as usize].finished_at = Some(self.now);
            self.requests[rid as usize].phase = Phase::Finished;
            self.relaxed[inst].kv.release(rid).expect("kv");
            self.kv_home[rid as usize] = KvHome::None;
            return;
        }
        if self.cfg.policy.offline_decode_on_relaxed() {
            // OOCO: decode right here; the strict pool pulls later (Alg. 1).
            self.requests[rid as usize].phase = Phase::Decoding;
            self.relaxed[inst].offline_decoding.push(rid);
        } else {
            // online priority: offline decode belongs to the strict pool.
            let target = self
                .router
                .route_decode(self.requests[rid as usize].kv_len());
            let kv_len = self.requests[rid as usize].kv_len();
            if self.strict[target].kv.can_fit(kv_len + 1) {
                self.strict[target].kv.admit(rid, kv_len + 1).expect("fit");
                self.relaxed[inst].kv.release(rid).expect("kv");
                self.kv_home[rid as usize] = KvHome::Strict(target);
                self.requests[rid as usize].phase = Phase::Migrating;
                self.strict[target].inbound.push(rid);
                let delay = self.pm.kv_transfer_latency(kv_len);
                self.queue.push(
                    self.now + delay,
                    EventKind::TransferDone {
                        req: rid,
                        strict: target,
                    },
                );
            } else {
                // Park on the relaxed node (holds KV, does not decode);
                // retried at strict step boundaries.
                self.router.decode_done(target, kv_len);
                self.relaxed[inst].offline_decoding.push(rid);
            }
        }
    }

    fn relaxed_decode_token(&mut self, inst: usize, rid: RequestId) {
        // Evicted/migrated-mid-step guard, O(1) via the location index
        // (migration moves kv_home to Strict; eviction resets it to None).
        if self.kv_home[rid as usize] != KvHome::Relaxed(inst) {
            return;
        }
        let done = self.requests[rid as usize].mark_token(self.now);
        if done {
            self.relaxed[inst].kv.release(rid).expect("kv");
            self.relaxed[inst].offline_decoding.retain(|&r| r != rid);
            self.kv_home[rid as usize] = KvHome::None;
            return;
        }
        if self.relaxed[inst].kv.grow(rid, 1).is_err() {
            self.evict_offline_from_relaxed(inst, rid);
        }
    }

    // ------------------------------------------------------ strict stepping

    fn strict_resident_stats(&self, inst: usize) -> BatchStats {
        let mut s = BatchStats::empty();
        for &r in self.strict[inst].online.iter().chain(&self.strict[inst].offline) {
            s = s.with(self.requests[r as usize].kv_len());
        }
        s
    }

    fn start_strict_step(&mut self, inst: usize) {
        if !self.strict[inst].is_idle() || !self.strict[inst].has_decode_work() {
            return;
        }
        let mut online: Vec<Candidate> = self.strict[inst]
            .online
            .iter()
            .map(|&r| (r, self.requests[r as usize].kv_len()))
            .collect();

        // §3.4.4 overload handling: in Shed mode, sacrifice the longest
        // online requests when even the online-only batch exceeds the SLO,
        // preserving the SLO for the remainder (OOCO only — baselines have
        // no latency predictor to act on).
        if self.cfg.overload_mode == OverloadMode::Shed
            && self.cfg.policy == Policy::Ooco
            && !online.is_empty()
        {
            let toks: usize = online.iter().map(|c| c.1).sum();
            let stats = BatchStats::new(online.len(), toks);
            if self.pm.decode_latency(stats) > self.cfg.serving.slo.tpot {
                let (kept, shed) = shed_online_overload(
                    &self.pm,
                    &online,
                    self.cfg.serving.slo.tpot,
                );
                for rid in shed {
                    let kv = self.requests[rid as usize].kv_len();
                    self.strict[inst].kv.release(rid).expect("resident");
                    self.strict[inst].remove_online(rid);
                    self.router.decode_done(inst, kv);
                    self.kv_home[rid as usize] = KvHome::None;
                    // Sacrificed: terminal, unfinished -> counts as an SLO
                    // violation in the report (the paper's trade).
                    self.requests[rid as usize].phase = Phase::Finished;
                }
                online = kept;
            }
        }
        let offline: Vec<Candidate> = self.strict[inst]
            .offline
            .iter()
            .map(|&r| (r, self.requests[r as usize].kv_len()))
            .collect();

        let slo = self.cfg.serving.slo.tpot;
        let selection = match self.cfg.policy {
            Policy::Ooco if self.cfg.ablation.mix_decode => select_decode_batch(
                &self.pm,
                &online,
                &offline,
                slo,
                self.cfg.serving.sched.mix_probe_iters,
                &mut self.rng,
            ),
            Policy::Ooco => select_decode_batch_capped(
                &online,
                &offline,
                self.cfg.serving.sched.baseline_decode_cap,
            ),
            Policy::OnlinePriority => select_decode_batch_capped(
                &online,
                &offline,
                self.cfg.serving.sched.baseline_decode_cap,
            ),
            Policy::BasePd => {
                // Everything is "online": batch all residents, no bound.
                select_decode_batch_capped(&online, &offline, usize::MAX)
            }
        };

        let mut participants: Vec<RequestId> =
            online.iter().map(|c| c.0).collect();
        participants.extend(&selection.offline);
        if participants.is_empty() {
            return;
        }
        let stats = selection.stats;
        let latency = self.pm.decode_latency(stats);
        let all_included =
            participants.len() == self.strict[inst].online.len() + self.strict[inst].offline.len();

        let seq = self.strict[inst].alloc_seq();
        let ends = self.now + latency.max(1e-9);
        self.strict[inst].step = Some(Step {
            kind: StepKind::DecodeStrict,
            started: self.now,
            ends,
            participants,
            seq,
            preempted: false,
        });
        self.strict[inst].busy_s += latency;
        self.strict[inst].steps += 1;
        // Stash per-step info for the migration decision at the boundary.
        self.strict_step_meta[inst] = Some((stats, all_included));
        self.queue.push(ends, EventKind::StrictStep { inst, seq });
    }

    fn on_strict_step_end(&mut self, inst: usize, seq: u64) {
        let valid = self.strict[inst]
            .step
            .as_ref()
            .map(|s| s.seq == seq)
            .unwrap_or(false);
        if !valid {
            return;
        }
        let step = self.strict[inst].step.take().expect("checked");
        for &rid in &step.participants {
            self.strict_decode_token(inst, rid);
        }
        // Step boundary work: retry waiting admissions, then migration pull.
        self.retry_waiting(inst);
        self.maybe_pull_migration(inst);
        self.pull_parked_offline(inst);
        self.start_strict_step(inst);
    }

    fn strict_decode_token(&mut self, inst: usize, rid: RequestId) {
        let is_online = self.requests[rid as usize].class.is_online()
            || self.cfg.policy == Policy::BasePd;
        // Evicted-mid-step guard. PERF (§Perf): O(1) via the kv_home
        // location index — the original `Vec::contains` residency check was
        // O(batch) per participant, O(batch^2) per step.
        if self.kv_home[rid as usize] != KvHome::Strict(inst) {
            return;
        }
        if self.requests[rid as usize].class == Class::Offline {
            self.strict[inst].offline_decode_tokens += 1;
        }
        let done = self.requests[rid as usize].mark_token(self.now);
        let kv = self.requests[rid as usize].kv_len();
        if done {
            self.strict[inst].kv.release(rid).expect("kv");
            if is_online {
                self.strict[inst].remove_online(rid);
            } else {
                self.strict[inst].remove_offline(rid);
            }
            self.router.decode_done(inst, kv);
            self.kv_home[rid as usize] = KvHome::None;
            return;
        }
        self.router.decode_grow(inst, 1);
        if self.strict[inst].kv.grow(rid, 1).is_err() {
            if is_online {
                // Free offline space for the online request's growth.
                self.make_room_on_strict(inst, self.cfg.block_tokens);
                if self.strict[inst].kv.grow(rid, 1).is_err() {
                    // True overload; token produced, KV undercounted by one
                    // block until space frees (documented approximation).
                }
            } else {
                self.evict_offline_from_strict(inst, rid);
            }
        }
    }

    /// Retry online requests that were waiting for strict KV space.
    fn retry_waiting(&mut self, inst: usize) {
        let mut remaining = VecDeque::new();
        while let Some(rid) = self.strict[inst].waiting_for_space.pop_front() {
            let kv_len = self.requests[rid as usize].kv_len();
            let need = kv_len + 1;
            if !self.strict[inst].kv.can_fit(need) {
                self.make_room_on_strict(inst, need);
            }
            if self.strict[inst].kv.can_fit(need) {
                let from = match self.kv_home[rid as usize] {
                    KvHome::Relaxed(i) => i,
                    _ => unreachable!("waiting request KV must be on relaxed"),
                };
                self.strict[inst].kv.admit(rid, need).expect("fit");
                self.relaxed[from].kv.release(rid).expect("kv");
                self.kv_home[rid as usize] = KvHome::Strict(inst);
                self.strict[inst].inbound.push(rid);
                let delay = self.pm.kv_transfer_latency(kv_len);
                self.queue.push(
                    self.now + delay,
                    EventKind::TransferDone { req: rid, strict: inst },
                );
            } else {
                remaining.push_back(rid);
            }
        }
        self.strict[inst].waiting_for_space = remaining;
    }

    /// Algorithm 1: pull offline decodes from relaxed nodes when headroom
    /// exists (OOCO only).
    fn maybe_pull_migration(&mut self, inst: usize) {
        if !self.cfg.policy.migration_enabled() || !self.cfg.ablation.migration {
            return;
        }
        let Some((stats, all_included)) = self.strict_step_meta[inst].take() else {
            return;
        };
        let pref = migration_decision(
            &self.pm,
            stats,
            all_included,
            self.cfg.serving.slo.tpot,
            self.cfg.serving.sched.slo_margin,
        );
        if pref == LengthPref::None {
            return;
        }
        // Pull from the relaxed instance with the largest offline pool.
        let Some(src) = (0..self.relaxed.len())
            .filter(|&i| !self.relaxed[i].offline_decoding.is_empty())
            .max_by_key(|&i| self.relaxed[i].offline_decoding.len())
        else {
            return;
        };
        let cands: Vec<Candidate> = self.relaxed[src]
            .offline_decoding
            .iter()
            .map(|&r| (r, self.requests[r as usize].kv_len()))
            .collect();
        let picked = pick_migration_candidates(
            pref,
            &cands,
            self.cfg.serving.sched.migration_batch,
        );
        for rid in picked {
            // Relaxed decode step may be running with this request; removal
            // from residency makes the in-flight token a no-op (guarded in
            // relaxed_decode_token).
            let kv_len = self.requests[rid as usize].kv_len();
            if !self.strict[inst].kv.can_fit(kv_len + 1) {
                break;
            }
            self.strict[inst].kv.admit(rid, kv_len + 1).expect("fit");
            self.relaxed[src].kv.release(rid).expect("kv");
            self.relaxed[src].offline_decoding.retain(|&r| r != rid);
            self.kv_home[rid as usize] = KvHome::Strict(inst);
            self.requests[rid as usize].phase = Phase::Migrating;
            self.router.route_decode(kv_len);
            self.strict[inst].inbound.push(rid);
            let delay = self.pm.kv_transfer_latency(kv_len);
            self.queue.push(
                self.now + delay,
                EventKind::TransferDone { req: rid, strict: inst },
            );
            self.migrations += 1;
        }
    }

    /// `online priority`: parked offline requests (prefilled on relaxed,
    /// waiting for strict space) move over as space frees — fit-only, no
    /// Algorithm 1.
    fn pull_parked_offline(&mut self, inst: usize) {
        if self.cfg.policy.offline_decode_on_relaxed()
            || self.cfg.policy == Policy::BasePd
        {
            return;
        }
        for src in 0..self.relaxed.len() {
            while let Some(&rid) = self.relaxed[src].offline_decoding.first() {
                let kv_len = self.requests[rid as usize].kv_len();
                if !self.strict[inst].kv.can_fit(kv_len + 1) {
                    return;
                }
                self.strict[inst].kv.admit(rid, kv_len + 1).expect("fit");
                self.relaxed[src].kv.release(rid).expect("kv");
                self.relaxed[src].offline_decoding.retain(|&r| r != rid);
                self.kv_home[rid as usize] = KvHome::Strict(inst);
                self.requests[rid as usize].phase = Phase::Migrating;
                self.router.route_decode(kv_len);
                self.strict[inst].inbound.push(rid);
                let delay = self.pm.kv_transfer_latency(kv_len);
                self.queue.push(
                    self.now + delay,
                    EventKind::TransferDone { req: rid, strict: inst },
                );
            }
        }
    }

    fn on_transfer_done(&mut self, rid: RequestId, inst: usize) {
        self.strict[inst].inbound.retain(|&r| r != rid);
        let is_online = self.requests[rid as usize].class.is_online()
            || self.cfg.policy == Policy::BasePd;
        self.requests[rid as usize].phase = Phase::Decoding;
        if is_online {
            self.strict[inst].online.push(rid);
        } else {
            self.strict[inst].offline.push(rid);
        }
        self.start_strict_step(inst);
    }

    // -------------------------------------------------------------- results

    fn build_result(self) -> SimResult {
        let mut recorder = Recorder::new();
        for r in &self.requests {
            recorder.record(r);
        }
        let duration = self.trace_end.max(1e-9);
        let report = recorder.report(&self.cfg.serving.slo, duration);
        let strict_busy: f64 = self.strict.iter().map(|s| s.busy_s).sum();
        let relaxed_busy: f64 = self.relaxed.iter().map(|s| s.busy_s).sum();
        SimResult {
            report,
            end_time: self.now,
            strict_utilization: strict_busy
                / (duration * self.strict.len() as f64),
            relaxed_utilization: relaxed_busy
                / (duration * self.relaxed.len() as f64),
            strict_steps: self.strict.iter().map(|s| s.steps).sum(),
            strict_offline_tokens: self
                .strict
                .iter()
                .map(|s| s.offline_decode_tokens)
                .sum(),
            preemptions: self.preemptions,
            evictions: self.evictions,
            migrations: self.migrations,
        }
    }
}
