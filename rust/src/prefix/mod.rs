//! Prefix-sharing KV cache subsystem (DESIGN.md §3.7): a radix-tree index
//! over hashed token blocks, layered on the refcounted
//! [`crate::kvcache::KvManager`].
//!
//! Offline co-located workloads (batch jobs over one system prompt,
//! few-shot templates, multi-turn agentic loops) overwhelmingly share
//! prompt prefixes. The simulator carries no real token content, so shared
//! content is modeled *by identity*: a request declares a prefix family and
//! a shareable length ([`crate::request::PrefixRef`]), and the first `len`
//! tokens of every request in a family are — by construction of the trace —
//! the same tokens. Block `i` of a family's chain therefore has a stable
//! [`BlockKey`] derived from `(family, i)`, exactly the role a content hash
//! of the block's tokens plays in a real engine (vLLM/SGLang-style
//! hash-block prefix caching).
//!
//! Each instance owns one [`PrefixIndex`] next to its `KvManager`. The
//! index maps key chains to *physical* blocks resident on that instance:
//!
//! - **lookup** walks the chain and returns the longest cached prefix as
//!   referencable full blocks plus, when the request's shareable span ends
//!   inside a block, one partially usable block (taken by copy-on-write —
//!   the block's leading tokens are reused, the copy diverges);
//! - **insert** registers a freshly prefilled chain, upgrading partial
//!   entries when a fuller version of the same block appears;
//! - **forget/purge** drop chain nodes whose blocks the allocator's LRU
//!   reclaimed (cached blocks are *reclaimable capacity*, not used
//!   capacity — see `KvManager::free_tokens`).
//!
//! Staleness is tolerated by validation instead of strict ordering: every
//! node dereference checks that its block is still cache-marked in the
//! allocator, so an LRU reclaim that has not yet been synced back into the
//! index can never hand out a reallocated block.

use std::collections::HashMap;

use crate::kvcache::KvManager;

/// Stable identity of one cached token block: stands in for a content hash
/// of the block's tokens.
pub type BlockKey = u64;

/// splitmix64 — deterministic across platforms (unlike `DefaultHasher`).
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Key of block `index` in `family`'s token chain.
pub fn chain_key(family: u64, index: usize) -> BlockKey {
    splitmix64(family ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Result of resolving a request's shareable prefix against an instance's
/// cache.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrefixMatch {
    /// Fully matched blocks, in chain order — referenced (refcounted) by
    /// the admitted request, zero recompute.
    pub full_blocks: Vec<u32>,
    /// Tokens covered by `full_blocks`.
    pub full_tokens: usize,
    /// A terminal partially usable block: `(block, tokens)` — reused by
    /// copy-on-write (the request's continuation diverges inside it).
    pub partial: Option<(u32, usize)>,
    /// Total prompt tokens whose KV needs no recompute
    /// (`full_tokens` + the partial contribution).
    pub cached_tokens: usize,
}

impl PrefixMatch {
    pub fn empty() -> Self {
        Self::default()
    }

    /// Cache entries this match touches (full + partial).
    pub fn cached_blocks(&self) -> usize {
        self.full_blocks.len() + usize::from(self.partial.is_some())
    }
}

#[derive(Debug, Clone)]
struct Node {
    key: BlockKey,
    /// Physical block in the co-resident `KvManager` holding this content.
    block: u32,
    /// Tokens of chain content in the block (== block size for interior
    /// nodes; the chain's last node may be partial).
    tokens: usize,
    parent: Option<usize>,
    children: Vec<usize>,
    live: bool,
}

/// Radix-tree prefix index of one instance (DESIGN.md §3.7). Chains with a
/// common ancestry share nodes: multi-turn agentic families extend one
/// path, distinct few-shot templates branch at the root.
#[derive(Debug)]
pub struct PrefixIndex {
    block_tokens: usize,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    /// Top-level chain heads (block 0 of each family).
    roots: Vec<usize>,
    /// Physical block -> node, for reclaim-driven removal.
    block_node: HashMap<u32, usize>,
    live: usize,
}

impl PrefixIndex {
    pub fn new(block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        PrefixIndex {
            block_tokens,
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: Vec::new(),
            block_node: HashMap::new(),
            live: 0,
        }
    }

    /// Number of cached chain entries.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    fn children_of(&self, parent: Option<usize>) -> &[usize] {
        match parent {
            Some(p) => &self.nodes[p].children,
            None => &self.roots,
        }
    }

    fn child_with_key(&self, parent: Option<usize>, key: BlockKey) -> Option<usize> {
        self.children_of(parent)
            .iter()
            .copied()
            .find(|&n| self.nodes[n].live && self.nodes[n].key == key)
    }

    /// Longest cached prefix of `family`'s chain usable for a request whose
    /// shareable span is `want` tokens. Pure: recency touching is the
    /// caller's job (`KvManager::touch_blocks`) so the borrow stays shared.
    pub fn lookup(&self, family: u64, want: usize, kv: &KvManager) -> PrefixMatch {
        let bt = self.block_tokens;
        let mut m = PrefixMatch::empty();
        let mut parent: Option<usize> = None;
        let mut i = 0usize;
        while m.cached_tokens < want {
            let Some(n) = self.child_with_key(parent, chain_key(family, i)) else {
                break;
            };
            let node = &self.nodes[n];
            // Stale-entry guard: the allocator's LRU may have reclaimed
            // this block before the index was synced.
            if !kv.is_cached(node.block) {
                break;
            }
            let remaining = want - m.cached_tokens;
            if node.tokens == bt && remaining >= bt {
                m.full_blocks.push(node.block);
                m.full_tokens += bt;
                m.cached_tokens += bt;
                parent = Some(n);
                i += 1;
            } else {
                // Terminal: either the cached block is partial, or the
                // request's shareable span ends inside this (full) block.
                // Its leading tokens are reused by copy-on-write.
                let t = node.tokens.min(remaining);
                if t > 0 {
                    m.partial = Some((node.block, t));
                    m.cached_tokens += t;
                }
                break;
            }
        }
        m
    }

    /// Register the first `upto` tokens of `family`'s chain, whose KV lives
    /// in `blocks` (the admitted request's block list, chain order).
    /// Existing entries are kept when at least as full, upgraded when this
    /// request carries a fuller version, and replaced when stale.
    pub fn insert(
        &mut self,
        family: u64,
        upto: usize,
        blocks: &[u32],
        kv: &mut KvManager,
    ) {
        let bt = self.block_tokens;
        let mut parent: Option<usize> = None;
        for (i, &block) in blocks.iter().enumerate() {
            let covered = i * bt;
            if covered >= upto {
                break;
            }
            let t = bt.min(upto - covered);
            let key = chain_key(family, i);
            let n = match self.child_with_key(parent, key) {
                Some(n)
                    if kv.is_cached(self.nodes[n].block)
                        && self.nodes[n].tokens >= t =>
                {
                    n // already cached as good or better
                }
                Some(n) => {
                    // Upgrade a partial (or stale) entry with our block.
                    // The replacement's coverage is exactly `t`: a stale
                    // full entry re-registered by a shallower chain must
                    // NOT keep its old token count, or lookups would serve
                    // family tokens the new block does not hold (and walk
                    // on into descendants never re-materialized).
                    let old = self.nodes[n].block;
                    if old != block {
                        kv.unmark_cached(old);
                        self.block_node.remove(&old);
                        self.drop_stale_mapping(block, kv);
                        self.nodes[n].block = block;
                        self.block_node.insert(block, n);
                    }
                    kv.mark_cached(block);
                    self.nodes[n].tokens = t;
                    n
                }
                None => {
                    self.drop_stale_mapping(block, kv);
                    let n = self.alloc_node(key, block, t, parent);
                    kv.mark_cached(block);
                    self.block_node.insert(block, n);
                    n
                }
            };
            if self.nodes[n].tokens < bt {
                break; // a partial block terminates the chain
            }
            parent = Some(n);
        }
    }

    fn alloc_node(
        &mut self,
        key: BlockKey,
        block: u32,
        tokens: usize,
        parent: Option<usize>,
    ) -> usize {
        let node = Node {
            key,
            block,
            tokens,
            parent,
            children: Vec::new(),
            live: true,
        };
        let n = match self.free_nodes.pop() {
            Some(n) => {
                self.nodes[n] = node;
                n
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        match parent {
            Some(p) => self.nodes[p].children.push(n),
            None => self.roots.push(n),
        }
        self.live += 1;
        n
    }

    /// A block being (re-)registered may still carry a stale mapping from
    /// a chain whose content was reclaimed and reallocated before the
    /// allocator log was synced; drop that old entry so one physical block
    /// never backs two chain positions.
    fn drop_stale_mapping(&mut self, block: u32, kv: &mut KvManager) {
        if let Some(&stale) = self.block_node.get(&block) {
            self.remove_subtree(stale, kv, block);
        }
    }

    /// Drop the chain entries of LRU-reclaimed `blocks` plus their (now
    /// unreachable) descendants. Returns how many *additional* blocks were
    /// unmarked from the cache beyond the input (descendant entries).
    pub fn forget_blocks(&mut self, blocks: &[u32], kv: &mut KvManager) -> usize {
        let mut extra = 0usize;
        for &b in blocks {
            let Some(&n) = self.block_node.get(&b) else {
                continue;
            };
            extra += self.remove_subtree(n, kv, b);
        }
        extra
    }

    /// Remove `n` and its whole subtree; count cache entries dropped other
    /// than `origin` (which the allocator already uncached).
    fn remove_subtree(&mut self, n: usize, kv: &mut KvManager, origin: u32) -> usize {
        // Detach from the parent first so the walk below owns the subtree.
        match self.nodes[n].parent {
            Some(p) => self.nodes[p].children.retain(|&c| c != n),
            None => self.roots.retain(|&c| c != n),
        }
        let mut dropped = 0usize;
        let mut stack = vec![n];
        while let Some(x) = stack.pop() {
            stack.extend(self.nodes[x].children.drain(..));
            let block = self.nodes[x].block;
            self.block_node.remove(&block);
            if block != origin && kv.is_cached(block) {
                kv.unmark_cached(block);
                dropped += 1;
            }
            self.nodes[x].live = false;
            self.free_nodes.push(x);
            self.live -= 1;
        }
        dropped
    }

    /// Drop every cached chain (drain-for-flip hygiene). Returns the number
    /// of cache entries removed.
    pub fn purge(&mut self, kv: &mut KvManager) -> usize {
        let mut dropped = 0usize;
        for n in 0..self.nodes.len() {
            if !self.nodes[n].live {
                continue;
            }
            let block = self.nodes[n].block;
            if kv.is_cached(block) {
                kv.unmark_cached(block);
            }
            dropped += 1;
            self.nodes[n].live = false;
            self.nodes[n].children.clear();
            self.free_nodes.push(n);
        }
        self.block_node.clear();
        self.roots.clear();
        self.live = 0;
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PrefixIndex, KvManager) {
        (PrefixIndex::new(16), KvManager::new(1600, 16))
    }

    /// Admit a request, hand its blocks to the caller, and register the
    /// chain (the shape of a prefill completion).
    fn prefill(
        idx: &mut PrefixIndex,
        kv: &mut KvManager,
        id: u64,
        family: u64,
        tokens: usize,
        upto: usize,
    ) -> Vec<u32> {
        kv.admit(id, tokens).unwrap();
        let blocks = kv.blocks_of(id).unwrap().to_vec();
        idx.insert(family, upto, &blocks, kv);
        blocks
    }

    #[test]
    fn lookup_matches_full_and_partial_blocks() {
        let (mut idx, mut kv) = setup();
        // 40 shareable tokens = 2 full blocks + 8 in the third.
        let blocks = prefill(&mut idx, &mut kv, 1, 7, 41, 40);
        assert_eq!(idx.len(), 3);

        let m = idx.lookup(7, 40, &kv);
        assert_eq!(m.full_blocks, blocks[..2].to_vec());
        assert_eq!(m.full_tokens, 32);
        assert_eq!(m.partial, Some((blocks[2], 8)));
        assert_eq!(m.cached_tokens, 40);

        // A shorter shareable span ends inside block 1: partial reuse of a
        // full block.
        let m = idx.lookup(7, 20, &kv);
        assert_eq!(m.full_blocks.len(), 1);
        assert_eq!(m.partial, Some((blocks[1], 4)));
        assert_eq!(m.cached_tokens, 20);

        // Unknown family: miss.
        assert_eq!(idx.lookup(8, 40, &kv), PrefixMatch::empty());
    }

    #[test]
    fn insert_upgrades_partial_entries() {
        let (mut idx, mut kv) = setup();
        prefill(&mut idx, &mut kv, 1, 7, 21, 20); // blocks 0 full, 1 partial(4)
        let m = idx.lookup(7, 40, &kv);
        assert_eq!(m.cached_tokens, 20);

        // A deeper request of the same family upgrades the chain.
        prefill(&mut idx, &mut kv, 2, 7, 49, 48);
        let m = idx.lookup(7, 48, &kv);
        assert_eq!(m.full_tokens, 48);
        assert_eq!(m.partial, None);
        assert_eq!(m.cached_tokens, 48);
    }

    #[test]
    fn stale_blocks_never_match() {
        let (mut idx, mut kv) = setup();
        let blocks = prefill(&mut idx, &mut kv, 1, 7, 33, 32);
        kv.release(1).unwrap(); // chain becomes reclaimable
        assert!(idx.lookup(7, 32, &kv).cached_tokens == 32);
        // Fill the pool: the allocator reclaims the LRU chain blocks.
        kv.admit(2, 1600).unwrap();
        let reclaimed = kv.take_reclaimed();
        assert!(!reclaimed.is_empty());
        // Unsynced index entries validate against the allocator and miss.
        assert_eq!(idx.lookup(7, 32, &kv), PrefixMatch::empty());
        let extra = idx.forget_blocks(&reclaimed, &mut kv);
        // Both chain entries drop (reclaimed blocks plus descendants).
        assert_eq!(idx.len(), 0);
        let _ = (blocks, extra);
    }

    #[test]
    fn forget_removes_descendants() {
        let (mut idx, mut kv) = setup();
        let blocks = prefill(&mut idx, &mut kv, 1, 7, 49, 48);
        kv.release(1).unwrap();
        assert_eq!(idx.len(), 3);
        // Simulate the allocator reclaiming the chain head.
        kv.unmark_cached(blocks[0]);
        let extra = idx.forget_blocks(&blocks[..1], &mut kv);
        assert_eq!(extra, 2, "both descendants drop with the head");
        assert_eq!(idx.len(), 0);
        assert_eq!(idx.lookup(7, 48, &kv), PrefixMatch::empty());
    }

    #[test]
    fn stale_full_entry_reregistered_shallower_shrinks_coverage() {
        // Regression: a stale node that used to be full must not keep its
        // old token count when a shallower chain re-registers it — lookups
        // would serve family tokens the new block does not hold.
        // Small 4-block pool so a full reclaim is easy to force.
        let mut idx = PrefixIndex::new(16);
        let mut kv = KvManager::new(64, 16);
        // Register a 48-token chain, release it, and force full reclaim.
        kv.admit(1, 48).unwrap();
        let blocks = kv.blocks_of(1).unwrap().to_vec();
        idx.insert(7, 48, &blocks, &mut kv);
        kv.release(1).unwrap();
        kv.admit(2, 64).unwrap(); // reclaims all three cached blocks
        kv.release(2).unwrap();
        // Note: the reclaim log is deliberately NOT synced (stale nodes).
        // A shallower registration (20 tokens: 1 full + 4 partial) reuses
        // the stale entries.
        kv.admit(3, 21).unwrap();
        let b3 = kv.blocks_of(3).unwrap().to_vec();
        idx.insert(7, 20, &b3, &mut kv);
        let m = idx.lookup(7, 48, &kv);
        assert_eq!(
            m.cached_tokens, 20,
            "coverage must shrink to the re-registered span, got {m:?}"
        );
        assert_eq!(m.full_blocks, vec![b3[0]]);
        assert_eq!(m.partial, Some((b3[1], 4)));
    }

    #[test]
    fn purge_clears_everything() {
        let (mut idx, mut kv) = setup();
        prefill(&mut idx, &mut kv, 1, 7, 49, 48);
        prefill(&mut idx, &mut kv, 2, 9, 33, 32);
        kv.release(1).unwrap();
        let dropped = idx.purge(&mut kv);
        assert_eq!(dropped, 5);
        assert!(idx.is_empty());
        assert_eq!(idx.lookup(7, 48, &kv), PrefixMatch::empty());
        // Released blocks went back to the free pool on unmark.
        kv.release(2).unwrap();
        assert_eq!(kv.free_blocks(), kv.total_blocks());
    }

    #[test]
    fn families_branch_at_the_root() {
        let (mut idx, mut kv) = setup();
        prefill(&mut idx, &mut kv, 1, 7, 33, 32);
        prefill(&mut idx, &mut kv, 2, 9, 33, 32);
        assert_eq!(idx.len(), 4);
        assert_eq!(idx.lookup(7, 32, &kv).cached_tokens, 32);
        assert_eq!(idx.lookup(9, 32, &kv).cached_tokens, 32);
    }

    #[test]
    fn chain_keys_are_stable_and_distinct() {
        assert_eq!(chain_key(7, 3), chain_key(7, 3));
        assert_ne!(chain_key(7, 3), chain_key(7, 4));
        assert_ne!(chain_key(7, 3), chain_key(8, 3));
    }
}
