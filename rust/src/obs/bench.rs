//! The `ooco bench` standardized workload suite (DESIGN.md §3.11).
//!
//! Four scenarios spanning the simulator's subsystems — plain co-located
//! serving, chunked-prefill long prompts, a prefix-heavy shared-system
//! workload, and a faulted two-replica fleet — each run with the
//! self-profiler armed. The result is the schema-stable `BENCH_sim.json`
//! (`schema: "ooco-bench-v1"`): headline requests/s, events/s,
//! per-subsystem breakdown, peak RSS, and config hash. CI runs the suite
//! on every PR and gates the headline against `BENCH_baseline.json`
//! (>20% regression fails), seeding the ROADMAP's bench trajectory.

use std::time::Instant;

use crate::config::{FaultSpec, ServingConfig};
use crate::coordinator::Policy;
use crate::fleet::{simulate_fleet_observed, FleetConfig};
use crate::sim::{simulate_observed, SimConfig};
use crate::trace::datasets::DatasetProfile;
use crate::trace::generator::{
    offline_trace_with_prefix, online_trace, PromptProfile,
};
use crate::trace::{PrefixProfile, Trace};
use crate::util::json::Json;

use super::{meta_json, peak_rss_bytes, ProfileReport};

/// Schema tag for `BENCH_sim.json`; bump when the layout changes so the
/// CI gate can refuse incomparable artifacts.
pub const BENCH_SCHEMA: &str = "ooco-bench-v1";

/// One scenario of the standardized suite.
pub struct BenchCase {
    pub name: &'static str,
    trace: Trace,
    sim: SimConfig,
    /// `Some` routes through the fleet layer.
    fleet: Option<FleetConfig>,
}

/// Outcome of one case: throughput figures plus the profiler breakdown.
pub struct BenchCaseResult {
    pub name: &'static str,
    pub requests: usize,
    pub events: u64,
    pub wall_s: f64,
    pub req_per_s: f64,
    pub events_per_s: f64,
    pub sim_end_s: f64,
    pub finished: usize,
    pub profile: ProfileReport,
}

impl BenchCaseResult {
    pub fn summary_line(&self) -> String {
        format!(
            "bench[{}]: {} req / {} ev in {:.3}s wall — {:.0} req/s, {:.0} ev/s | {}",
            self.name,
            self.requests,
            self.events,
            self.wall_s,
            self.req_per_s,
            self.events_per_s,
            self.profile.summary_line(),
        )
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("events", Json::Num(self.events as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("req_per_s", Json::Num(self.req_per_s)),
            ("events_per_s", Json::Num(self.events_per_s)),
            ("sim_end_s", Json::Num(self.sim_end_s)),
            ("finished", Json::Num(self.finished as f64)),
            ("profile", self.profile.to_json()),
        ])
    }
}

/// Build the standardized suite. `scale` multiplies every scenario's
/// trace duration (1.0 is the CI/trajectory configuration; tests use a
/// small fraction); `seed` feeds every generator and simulator.
pub fn standard_suite(scale: f64, seed: u64) -> Vec<BenchCase> {
    let mut cases = Vec::new();

    // 1. single-cluster: the paper's co-located baseline, dense offline
    //    load through migrations/evictions/transport.
    {
        let dur = 600.0 * scale;
        let trace = online_trace(DatasetProfile::azure_conv(), 0.5, dur, seed)
            .merge(offline_trace_with_prefix(
                DatasetProfile::ooc_offline(),
                10.0,
                dur,
                PrefixProfile::None,
                seed + 1,
            ));
        let mut sim = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        sim.seed = seed;
        cases.push(BenchCase {
            name: "single-cluster",
            trace,
            sim,
            fleet: None,
        });
    }

    // 2. chunked: long prompts under the auto chunk budget (§3.8) —
    //    stresses the chunk solver and preemption bookkeeping.
    {
        let dur = 300.0 * scale;
        let prompt: PromptProfile = "long-prompt(mean=8000,sigma=0.8,max=16384)"
            .parse()
            .expect("static profile");
        let trace = online_trace(
            prompt.apply(&DatasetProfile::azure_conv()),
            0.5,
            dur,
            seed,
        )
        .merge(offline_trace_with_prefix(
            prompt.apply(&DatasetProfile::ooc_offline()),
            0.5,
            dur,
            PrefixProfile::None,
            seed + 1,
        ));
        let mut serving = ServingConfig::preset_7b();
        serving.chunk_tokens = "auto".parse().expect("static chunk mode");
        let mut sim = SimConfig::new(serving, Policy::Ooco);
        sim.seed = seed;
        cases.push(BenchCase {
            name: "chunked",
            trace,
            sim,
            fleet: None,
        });
    }

    // 3. prefix-heavy: shared-system offline prompts (§3.7) — stresses
    //    the radix cache, COW admissions, and eviction flushes.
    {
        let dur = 300.0 * scale;
        let prefix: PrefixProfile =
            "shared-system(len=1024)".parse().expect("static profile");
        let trace = online_trace(DatasetProfile::azure_conv(), 0.3, dur, seed)
            .merge(offline_trace_with_prefix(
                DatasetProfile::ooc_offline(),
                4.0,
                dur,
                prefix,
                seed + 1,
            ));
        let mut sim = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        sim.seed = seed;
        cases.push(BenchCase {
            name: "prefix-heavy",
            trace,
            sim,
            fleet: None,
        });
    }

    // 4. faulted-fleet: two replicas, a mid-run noticed crash (§3.9) —
    //    stresses routing, evacuation transport, and recovery.
    {
        let dur = 240.0 * scale;
        let trace = online_trace(DatasetProfile::azure_conv(), 0.5, dur, seed)
            .merge(offline_trace_with_prefix(
                DatasetProfile::ooc_offline(),
                2.0,
                dur,
                PrefixProfile::None,
                seed + 1,
            ));
        let mut serving = ServingConfig::preset_7b();
        serving.cluster.relaxed_instances = 2;
        serving.cluster.strict_instances = 2;
        let mut sim = SimConfig::new(serving, Policy::Ooco);
        sim.seed = seed;
        let fault: FaultSpec = format!(
            "crash(at={},pool=relaxed,inst=1,down={},notice={})",
            60.0 * scale,
            60.0 * scale,
            20.0 * scale
        )
        .parse()
        .expect("static fault spec");
        let mut fleet = FleetConfig::new(sim.clone());
        fleet.fleet.replicas = 2;
        fleet.fault = fault;
        cases.push(BenchCase {
            name: "faulted-fleet",
            trace,
            sim,
            fleet: Some(fleet),
        });
    }

    cases
}

/// Run one case with the profiler armed and wall-clock measured.
pub fn run_case(case: &BenchCase) -> BenchCaseResult {
    let started = Instant::now();
    let (events, end_time, finished, profile) = match &case.fleet {
        Some(fcfg) => {
            let res = simulate_fleet_observed(&case.trace, fcfg, None, true);
            (
                res.events,
                res.end_time,
                res.report.online_finished + res.report.offline_finished,
                res.profile.expect("profiling was requested"),
            )
        }
        None => {
            let res = simulate_observed(&case.trace, &case.sim, None, true);
            (
                res.events,
                res.end_time,
                res.report.online_finished + res.report.offline_finished,
                res.profile.expect("profiling was requested"),
            )
        }
    };
    let wall_s = started.elapsed().as_secs_f64().max(1e-9);
    BenchCaseResult {
        name: case.name,
        requests: case.trace.len(),
        events,
        wall_s,
        req_per_s: case.trace.len() as f64 / wall_s,
        events_per_s: events as f64 / wall_s,
        sim_end_s: end_time,
        finished,
        profile,
    }
}

/// A canonical description of every case's configuration, hashed into the
/// suite's `meta.config_hash` so trajectory points are comparable only
/// when the suite definition matches.
fn suite_config_desc(cases: &[BenchCase]) -> String {
    let mut desc = format!("schema={BENCH_SCHEMA};");
    for c in cases {
        desc.push_str(&format!(
            "{}:requests={},sim={:?},fleet={:?};",
            c.name,
            c.trace.len(),
            c.sim,
            c.fleet.as_ref().map(|f| (&f.fleet, &f.fault)),
        ));
    }
    desc
}

/// Run the full suite and compose `BENCH_sim.json`. Returns the JSON and
/// the per-case human summaries (printed by the CLI).
pub fn run_suite(scale: f64, seed: u64) -> (Json, Vec<String>) {
    let cases = standard_suite(scale, seed);
    let desc = suite_config_desc(&cases);
    let started = Instant::now();
    let results: Vec<BenchCaseResult> = cases.iter().map(run_case).collect();
    let total_wall = started.elapsed().as_secs_f64().max(1e-9);

    let total_requests: usize = results.iter().map(|r| r.requests).sum();
    let total_events: u64 = results.iter().map(|r| r.events).sum();
    // Headline: whole-suite requests per wall second — one number that
    // moves when any scenario's hot path regresses.
    let headline = total_requests as f64 / total_wall;

    let summaries: Vec<String> =
        results.iter().map(|r| r.summary_line()).collect();
    let json = Json::obj(vec![
        ("schema", Json::Str(BENCH_SCHEMA.to_string())),
        ("meta", meta_json(seed, &desc, total_wall)),
        ("scale", Json::Num(scale)),
        ("headline_req_per_s", Json::Num(headline)),
        (
            "total",
            Json::obj(vec![
                ("requests", Json::Num(total_requests as f64)),
                ("events", Json::Num(total_events as f64)),
                ("wall_s", Json::Num(total_wall)),
                (
                    "events_per_s",
                    Json::Num(total_events as f64 / total_wall),
                ),
            ]),
        ),
        ("peak_rss_bytes", Json::Num(peak_rss_bytes() as f64)),
        (
            "cases",
            Json::Arr(results.iter().map(|r| r.to_json()).collect()),
        ),
    ]);
    (json, summaries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::config_hash;

    #[test]
    fn suite_has_four_scenarios() {
        let cases = standard_suite(0.01, 42);
        let names: Vec<&str> = cases.iter().map(|c| c.name).collect();
        assert_eq!(
            names,
            ["single-cluster", "chunked", "prefix-heavy", "faulted-fleet"]
        );
        assert!(cases.iter().all(|c| !c.trace.is_empty()));
        assert!(cases[3].fleet.is_some());
    }

    #[test]
    fn suite_config_hash_is_seed_stable() {
        let a = config_hash(&suite_config_desc(&standard_suite(0.01, 42)));
        let b = config_hash(&suite_config_desc(&standard_suite(0.01, 42)));
        let c = config_hash(&suite_config_desc(&standard_suite(0.02, 42)));
        assert_eq!(a, b);
        assert_ne!(a, c, "scale changes the suite definition");
    }
}
