//! OpenMetrics/Prometheus text exporter (`--metrics-out`).
//!
//! Renders a composed `--json-out` report object into the OpenMetrics
//! text format so sim and engine runs speak a standard monitoring
//! format: every numeric leaf becomes an `ooco_*` gauge family with
//! `# HELP`/`# TYPE` lines, string leaves collect into one
//! `ooco_run_info` family, the flight-recorder gauge `timeline` renders
//! as timestamped samples (sim-time seconds), transport links get a
//! `link` label, and the exposition terminates with `# EOF`.
//!
//! Family names are unique by construction (one `BTreeMap` entry per
//! family), which is exactly what `tests/obs_properties.rs` validates.

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Default)]
struct Family {
    help: String,
    /// (label string incl. braces or empty, value, optional timestamp).
    samples: Vec<(String, f64, Option<f64>)>,
}

/// Render `root` (a `--json-out`-shaped object) as OpenMetrics text.
pub fn render(root: &Json) -> String {
    let mut fams: BTreeMap<String, Family> = BTreeMap::new();
    let mut info: Vec<(String, String)> = Vec::new();
    if let Some(obj) = root.as_obj() {
        for (key, val) in obj {
            match key.as_str() {
                "timeline" => render_timeline(&mut fams, val),
                "incidents" => render_incidents(&mut fams, val),
                _ => walk(&mut fams, &mut info, &[sanitize(key)], val),
            }
        }
    }
    if !info.is_empty() {
        let fam = fams.entry("ooco_run_info".to_string()).or_default();
        fam.help =
            "String-valued run attributes as key/value labels.".to_string();
        for (k, v) in info {
            fam.samples.push((
                format!("{{key=\"{}\",value=\"{}\"}}", escape(&k), escape(&v)),
                1.0,
                None,
            ));
        }
    }

    let mut out = String::new();
    for (name, fam) in &fams {
        out.push_str(&format!("# HELP {name} {}\n", fam.help));
        out.push_str(&format!("# TYPE {name} gauge\n"));
        for (labels, value, ts) in &fam.samples {
            out.push_str(name);
            out.push_str(labels);
            out.push(' ');
            out.push_str(&fmt_value(*value));
            if let Some(t) = ts {
                out.push(' ');
                out.push_str(&fmt_value(*t));
            }
            out.push('\n');
        }
    }
    out.push_str("# EOF\n");
    out
}

/// Generic recursive flattening: objects extend the metric path, numeric
/// and boolean leaves emit samples, strings collect into the info family,
/// arrays are skipped (the shaped ones — `timeline`, transport `links` —
/// are special-cased before we get here).
fn walk(
    fams: &mut BTreeMap<String, Family>,
    info: &mut Vec<(String, String)>,
    path: &[String],
    v: &Json,
) {
    match v {
        Json::Num(n) => emit(fams, path, None, *n, None),
        Json::Bool(b) => {
            emit(fams, path, None, if *b { 1.0 } else { 0.0 }, None)
        }
        Json::Str(s) => info.push((path.join("_"), s.clone())),
        Json::Obj(o) => {
            for (k, val) in o {
                let mut p = path.to_vec();
                p.push(sanitize(k));
                walk(fams, info, &p, val);
            }
        }
        Json::Arr(items) => {
            // Transport's per-link rows are the one labelled array shape.
            if path.last().map(|s| s.as_str()) == Some("links") {
                render_links(fams, path, items);
            }
        }
        Json::Null => {}
    }
}

/// The flight recorder's gauge timeline: one timestamped gauge family per
/// sample key, labelled by replica when present. Timestamps are sim-time
/// seconds — the run's own clock, which is what the gauges are plotted
/// against.
fn render_timeline(fams: &mut BTreeMap<String, Family>, timeline: &Json) {
    let Some(samples) = timeline.as_arr() else {
        return;
    };
    for sample in samples {
        let Some(obj) = sample.as_obj() else { continue };
        let t = sample.get("t").as_f64();
        let replica = sample.get("replica").as_u64();
        let labels = replica
            .map(|r| format!("{{replica=\"{r}\"}}"))
            .unwrap_or_default();
        for (k, v) in obj {
            if k == "t" || k == "replica" {
                continue;
            }
            if let Some(n) = v.as_f64() {
                let path =
                    ["timeline".to_string(), sanitize(k)];
                emit(fams, &path, Some(labels.clone()), n, t);
            }
        }
    }
}

/// The incident engine's summary (DESIGN.md §3.12): open/total incident
/// counts and the final multi-window burn-rate readings as labelled
/// families, instead of the generic walk (whose flattening would mangle
/// the per-incident array).
fn render_incidents(fams: &mut BTreeMap<String, Family>, inc: &Json) {
    {
        let fam =
            fams.entry("ooco_incidents_active".to_string()).or_default();
        fam.help = "Incidents still open when the run ended.".to_string();
        fam.samples.push((
            String::new(),
            inc.get("open_at_end").as_f64().unwrap_or(0.0),
            None,
        ));
    }
    if let Some(by_kind) =
        inc.get("by_kind").as_obj().filter(|m| !m.is_empty())
    {
        let fam =
            fams.entry("ooco_incidents_total".to_string()).or_default();
        fam.help = "Incidents opened over the run, by kind.".to_string();
        for (kind, n) in by_kind {
            if let Some(n) = n.as_f64() {
                fam.samples.push((
                    format!("{{kind=\"{}\"}}", escape(kind)),
                    n,
                    None,
                ));
            }
        }
    }
    if let Some(burn) = inc.get("burn").as_obj() {
        let fam = fams.entry("ooco_burn_rate".to_string()).or_default();
        fam.help = "Final error-budget burn rates for the online class, \
                    per SLO metric and alert window."
            .to_string();
        for (metric, windows) in burn {
            for window in ["fast", "slow"] {
                if let Some(v) = windows.get(window).as_f64() {
                    fam.samples.push((
                        format!(
                            "{{class=\"online-{}\",window=\"{window}\"}}",
                            escape(metric)
                        ),
                        v,
                        None,
                    ));
                }
            }
        }
    }
    if let Some(wins) =
        inc.get("bottleneck_windows").as_obj().filter(|m| !m.is_empty())
    {
        let fam =
            fams.entry("ooco_bottleneck_windows".to_string()).or_default();
        fam.help = "Roofline-classified instance-windows, by dominant \
                    bottleneck label."
            .to_string();
        for (label, n) in wins {
            if let Some(n) = n.as_f64() {
                fam.samples.push((
                    format!("{{label=\"{}\"}}", escape(label)),
                    n,
                    None,
                ));
            }
        }
    }
}

fn render_links(
    fams: &mut BTreeMap<String, Family>,
    path: &[String],
    links: &[Json],
) {
    for link in links {
        let name = link.get("name").as_str().unwrap_or("unnamed");
        let labels = format!("{{link=\"{}\"}}", escape(name));
        if let Some(obj) = link.as_obj() {
            for (k, v) in obj {
                if k == "name" {
                    continue;
                }
                if let Some(n) = v.as_f64() {
                    let mut p = path.to_vec();
                    p.pop(); // replace the trailing "links" segment
                    p.push("link".to_string());
                    p.push(sanitize(k));
                    emit(fams, &p, Some(labels.clone()), n, None);
                }
            }
        }
    }
}

fn emit(
    fams: &mut BTreeMap<String, Family>,
    path: &[String],
    labels: Option<String>,
    value: f64,
    ts: Option<f64>,
) {
    let name = format!("ooco_{}", path.join("_"));
    let fam = fams.entry(name).or_default();
    if fam.help.is_empty() {
        fam.help = format!("OOCO report field {}.", path.join("."));
    }
    fam.samples.push((labels.unwrap_or_default(), value, ts));
}

/// Metric-name charset: `[a-zA-Z0-9_]`, everything else folds to `_`.
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars_and_eof() {
        let root = Json::obj(vec![
            (
                "report",
                Json::obj(vec![
                    ("duration_s", Json::Num(10.0)),
                    ("online_total", Json::Num(3.0)),
                ]),
            ),
            ("policy", Json::Str("ooco".to_string())),
        ]);
        let text = render(&root);
        assert!(text.contains("# HELP ooco_report_duration_s "));
        assert!(text.contains("# TYPE ooco_report_duration_s gauge"));
        assert!(text.contains("\nooco_report_duration_s 10\n"));
        assert!(text
            .contains("ooco_run_info{key=\"policy\",value=\"ooco\"} 1"));
        assert!(text.ends_with("# EOF\n"));
    }

    #[test]
    fn timeline_gets_timestamps_and_replica_labels() {
        let root = Json::obj(vec![(
            "timeline",
            Json::Arr(vec![Json::obj(vec![
                ("t", Json::Num(5.0)),
                ("replica", Json::Num(1.0)),
                ("online_queue", Json::Num(4.0)),
            ])]),
        )]);
        let text = render(&root);
        assert!(
            text.contains("ooco_timeline_online_queue{replica=\"1\"} 4 5"),
            "{text}"
        );
    }

    #[test]
    fn links_get_link_labels() {
        let root = Json::obj(vec![(
            "transport",
            Json::obj(vec![(
                "links",
                Json::Arr(vec![Json::obj(vec![
                    ("name", Json::Str("pool".to_string())),
                    ("busy_s", Json::Num(2.5)),
                ])]),
            )]),
        )]);
        let text = render(&root);
        assert!(
            text.contains("ooco_transport_link_busy_s{link=\"pool\"} 2.5"),
            "{text}"
        );
    }

    #[test]
    fn incidents_render_as_labelled_families() {
        let root = Json::obj(vec![(
            "incidents",
            Json::obj(vec![
                ("open_at_end", Json::Num(1.0)),
                (
                    "by_kind",
                    Json::obj(vec![
                        ("fault", Json::Num(2.0)),
                        ("slo_burn", Json::Num(1.0)),
                    ]),
                ),
                (
                    "burn",
                    Json::obj(vec![(
                        "ttft",
                        Json::obj(vec![
                            ("fast", Json::Num(6.5)),
                            ("slow", Json::Num(3.25)),
                        ]),
                    )]),
                ),
                (
                    "bottleneck_windows",
                    Json::obj(vec![("queue", Json::Num(7.0))]),
                ),
            ]),
        )]);
        let text = render(&root);
        assert!(text.contains("\nooco_incidents_active 1\n"), "{text}");
        assert!(
            text.contains("ooco_incidents_total{kind=\"fault\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("ooco_incidents_total{kind=\"slo_burn\"} 1"),
            "{text}"
        );
        assert!(
            text.contains(
                "ooco_burn_rate{class=\"online-ttft\",window=\"fast\"} 6.5"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "ooco_burn_rate{class=\"online-ttft\",window=\"slow\"} 3.25"
            ),
            "{text}"
        );
        assert!(
            text.contains("ooco_bottleneck_windows{label=\"queue\"} 7"),
            "{text}"
        );
    }

    #[test]
    fn family_names_are_unique() {
        let root = Json::obj(vec![
            ("a", Json::obj(vec![("x", Json::Num(1.0))])),
            ("b", Json::obj(vec![("x", Json::Num(2.0))])),
        ]);
        let text = render(&root);
        let helps: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("# HELP"))
            .collect();
        let mut dedup = helps.clone();
        dedup.dedup();
        assert_eq!(helps.len(), dedup.len());
    }
}
