//! Performance observatory: self-profiling probes, run metadata, and the
//! standardized bench suite (DESIGN.md §3.11).
//!
//! PR 7's flight recorder observes the *simulated workload*; this module
//! observes the *simulator itself* — the profiling-before-optimizing
//! discipline the ROADMAP's order-of-magnitude speedup item needs. Three
//! pieces:
//!
//! - **Scoped probes** ([`scope`], [`Subsystem`]): a thread-local profiler
//!   accumulating per-subsystem *self* wall-time (exclusive: entering a
//!   nested scope pauses the parent's attribution), call counts, and
//!   per-event-type tallies. Disabled, every probe is one thread-local
//!   branch and zero clock reads; enabled, probes read clocks but never
//!   touch simulation state, so same-seed runs stay byte-identical
//!   (`tests/obs_properties.rs` pins this).
//! - **[`ProfileReport`]**: the `profile` key of `--json-out`, whose
//!   per-subsystem breakdown must cover ≥90% of the measured span.
//! - **Run metadata** ([`meta_json`], [`config_hash`], [`peak_rss_bytes`])
//!   and the [`bench`] suite / [`openmetrics`] exporter built on top.

pub mod bench;
pub mod openmetrics;

use std::cell::{Cell, RefCell};
use std::time::Instant;

use crate::util::json::Json;

// ------------------------------------------------------------- subsystems

/// The instrumented subsystems. Every hot-path probe charges one of these
/// buckets; the uninstrumented remainder (loop control, event dispatch
/// branches) is the `1 - coverage` residual of [`ProfileReport`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Subsystem {
    /// Core/executor construction: request-table clones, heap seeding.
    Setup,
    /// Event-heap pops (the loop's ordering work).
    HeapPop,
    /// Event-heap pushes while applying the core's action stream.
    HeapPush,
    /// `SchedulerCore` decision entry points (§3.4 loop).
    Scheduler,
    /// Transport progress: chunk completions and job hand-offs.
    Transport,
    /// Prefix-cache lookups, inserts, and eviction flushes (§3.7).
    Prefix,
    /// Elastic pool re-planning heartbeat (§3.6).
    Pool,
    /// Fleet-only work: admission routing and work stealing (§3.9).
    Fleet,
    /// Flight-recorder taps and gauge sampling (§3.10).
    Telemetry,
    /// Metrics accumulation and report building.
    Metrics,
}

const N_SUB: usize = 10;

const SUB_NAMES: [&str; N_SUB] = [
    "setup",
    "heap_pop",
    "heap_push",
    "scheduler",
    "transport",
    "prefix",
    "pool",
    "fleet",
    "telemetry",
    "metrics",
];

impl Subsystem {
    fn idx(self) -> usize {
        match self {
            Subsystem::Setup => 0,
            Subsystem::HeapPop => 1,
            Subsystem::HeapPush => 2,
            Subsystem::Scheduler => 3,
            Subsystem::Transport => 4,
            Subsystem::Prefix => 5,
            Subsystem::Pool => 6,
            Subsystem::Fleet => 7,
            Subsystem::Telemetry => 8,
            Subsystem::Metrics => 9,
        }
    }

    pub fn name(self) -> &'static str {
        SUB_NAMES[self.idx()]
    }
}

/// Event classes tallied per popped loop event (one count per event, so
/// the tally sum equals the loop's event total).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventClass {
    Arrival,
    RelaxedStep,
    StrictStep,
    TransferChunk,
    CrashNotice,
    Crash,
    Recover,
}

const N_EV: usize = 7;

const EV_NAMES: [&str; N_EV] = [
    "arrival",
    "relaxed_step",
    "strict_step",
    "transfer_chunk",
    "crash_notice",
    "crash",
    "recover",
];

impl EventClass {
    fn idx(self) -> usize {
        match self {
            EventClass::Arrival => 0,
            EventClass::RelaxedStep => 1,
            EventClass::StrictStep => 2,
            EventClass::TransferChunk => 3,
            EventClass::CrashNotice => 4,
            EventClass::Crash => 5,
            EventClass::Recover => 6,
        }
    }

    pub fn name(self) -> &'static str {
        EV_NAMES[self.idx()]
    }
}

// --------------------------------------------------------------- profiler

#[derive(Debug)]
struct ProfState {
    started: Option<Instant>,
    self_s: [f64; N_SUB],
    calls: [u64; N_SUB],
    events: [u64; N_EV],
    /// Open scopes: (subsystem index, start of the current *self* segment).
    /// Entering a child attributes the parent's open segment and restarts
    /// it on exit — exclusive accounting, so buckets sum to ≤ total.
    stack: Vec<(usize, Instant)>,
}

impl ProfState {
    const fn new() -> Self {
        ProfState {
            started: None,
            self_s: [0.0; N_SUB],
            calls: [0; N_SUB],
            events: [0; N_EV],
            stack: Vec::new(),
        }
    }

    fn reset(&mut self) {
        *self = ProfState::new();
    }
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static STATE: RefCell<ProfState> = const { RefCell::new(ProfState::new()) };
}

/// Arm this thread's profiler and start the measured span. Resets any
/// prior accumulation.
pub fn enable() {
    STATE.with(|s| {
        let mut st = s.borrow_mut();
        st.reset();
        st.started = Some(Instant::now());
    });
    ENABLED.with(|e| e.set(true));
}

/// Is this thread's profiler armed? (One thread-local read — the whole
/// cost of a disabled probe.)
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Scope guard: charges `sub` for the wall time between construction and
/// drop, minus any nested scopes (self-time accounting). No-op (and no
/// clock read) when the profiler is disabled.
#[must_use = "the scope measures until dropped — bind it with `let _p = ...`"]
pub struct Scope {
    active: bool,
}

#[inline]
pub fn scope(sub: Subsystem) -> Scope {
    if !is_enabled() {
        return Scope { active: false };
    }
    let now = Instant::now();
    STATE.with(|s| {
        let st = &mut *s.borrow_mut();
        if let Some(&(top, seg)) = st.stack.last() {
            st.self_s[top] += now.duration_since(seg).as_secs_f64();
        }
        st.stack.push((sub.idx(), now));
    });
    Scope { active: true }
}

impl Drop for Scope {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let now = Instant::now();
        STATE.with(|s| {
            let st = &mut *s.borrow_mut();
            if let Some((sub, seg)) = st.stack.pop() {
                st.self_s[sub] += now.duration_since(seg).as_secs_f64();
                st.calls[sub] += 1;
            }
            if let Some(top) = st.stack.last_mut() {
                top.1 = now; // resume the parent's self segment
            }
        });
    }
}

/// Tally one popped loop event. Call exactly once per event so the tally
/// sum equals the loop's event total.
#[inline]
pub fn count_event(ev: EventClass) {
    if !is_enabled() {
        return;
    }
    STATE.with(|s| s.borrow_mut().events[ev.idx()] += 1);
}

/// Disarm the profiler and build the report over the span since
/// [`enable`]. All open scopes must have dropped by now.
pub fn take_report() -> ProfileReport {
    ENABLED.with(|e| e.set(false));
    STATE.with(|s| {
        let mut st = s.borrow_mut();
        debug_assert!(st.stack.is_empty(), "unbalanced profiler scopes");
        let total_s = st
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        let covered_s: f64 = st.self_s.iter().sum();
        let subsystems = (0..N_SUB)
            .filter(|&i| st.calls[i] > 0)
            .map(|i| SubsystemStat {
                name: SUB_NAMES[i],
                calls: st.calls[i],
                self_s: st.self_s[i],
            })
            .collect();
        let events = (0..N_EV)
            .filter(|&i| st.events[i] > 0)
            .map(|i| (EV_NAMES[i], st.events[i]))
            .collect();
        let report = ProfileReport {
            total_s,
            covered_s,
            coverage: if total_s > 0.0 {
                covered_s / total_s
            } else {
                0.0
            },
            subsystems,
            events,
        };
        st.reset();
        report
    })
}

// ----------------------------------------------------------------- report

/// One subsystem's share of the measured span.
#[derive(Debug, Clone)]
pub struct SubsystemStat {
    pub name: &'static str,
    /// Scope entries (probe invocations), not loop events.
    pub calls: u64,
    /// Exclusive (self) wall time, seconds.
    pub self_s: f64,
}

/// Per-subsystem wall-time breakdown of one profiled run — the `profile`
/// key of `--json-out`. Wall times are inherently non-deterministic;
/// everything else in the report stays byte-identical across same-seed
/// runs (the probes are pure observers).
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// The full measured span: [`enable`] → [`take_report`].
    pub total_s: f64,
    /// Sum of per-subsystem self times.
    pub covered_s: f64,
    /// `covered_s / total_s` — the acceptance bar is ≥ 0.9.
    pub coverage: f64,
    /// Subsystems with at least one probe hit, in declaration order.
    pub subsystems: Vec<SubsystemStat>,
    /// Per-event-type tallies; the sum is the loop's event total.
    pub events: Vec<(&'static str, u64)>,
}

impl ProfileReport {
    /// Total loop events (sum of the per-type tallies).
    pub fn event_total(&self) -> u64 {
        self.events.iter().map(|(_, n)| n).sum()
    }

    /// One-line summary for bench/CLI output, hottest subsystem first.
    pub fn summary_line(&self) -> String {
        let mut ranked: Vec<&SubsystemStat> = self.subsystems.iter().collect();
        ranked.sort_by(|a, b| {
            b.self_s
                .partial_cmp(&a.self_s)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let parts: Vec<String> = ranked
            .iter()
            .take(4)
            .map(|s| {
                format!(
                    "{} {:.0}%",
                    s.name,
                    100.0 * s.self_s / self.total_s.max(1e-12)
                )
            })
            .collect();
        format!(
            "profile: {:.3}s measured, {:.1}% covered | {}",
            self.total_s,
            self.coverage * 100.0,
            parts.join(" | ")
        )
    }

    pub fn to_json(&self) -> Json {
        let subsystems = Json::Obj(
            self.subsystems
                .iter()
                .map(|s| {
                    (
                        s.name.to_string(),
                        Json::obj(vec![
                            ("calls", Json::Num(s.calls as f64)),
                            ("self_s", Json::Num(s.self_s)),
                            (
                                "frac",
                                Json::Num(s.self_s / self.total_s.max(1e-12)),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        let events = Json::Obj(
            self.events
                .iter()
                .map(|(k, n)| (k.to_string(), Json::Num(*n as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("total_s", Json::Num(self.total_s)),
            ("covered_s", Json::Num(self.covered_s)),
            ("coverage", Json::Num(self.coverage)),
            ("subsystems", subsystems),
            ("event_counts", events),
        ])
    }
}

// ------------------------------------------------------------ run metadata

/// FNV-1a 64-bit — tiny, dependency-free, stable across runs; used for
/// the config hash in the `meta` header so archived artifacts are
/// attributable to an exact configuration.
pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash a canonical config description (e.g. `format!("{cfg:?}")`) to a
/// 16-hex-digit token.
pub fn config_hash(desc: &str) -> String {
    format!("{:016x}", fnv1a64(desc))
}

/// Self-describing `meta` header attached to every `--json-out` report:
/// crate version, seed, config hash, and wall-clock duration. `wall_s` is
/// the only non-deterministic field.
pub fn meta_json(seed: u64, config_desc: &str, wall_s: f64) -> Json {
    Json::obj(vec![
        ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
        ("seed", Json::Num(seed as f64)),
        ("config_hash", Json::Str(config_hash(config_desc))),
        ("wall_s", Json::Num(wall_s)),
    ])
}

/// Peak resident set size in bytes (`VmHWM` from `/proc/self/status`);
/// 0 where the procfs interface is unavailable.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_probes_are_inert() {
        assert!(!is_enabled());
        {
            let _p = scope(Subsystem::Scheduler);
            count_event(EventClass::Arrival);
        }
        let rep = take_report();
        assert_eq!(rep.total_s, 0.0);
        assert!(rep.subsystems.is_empty());
        assert!(rep.events.is_empty());
    }

    #[test]
    fn nested_scopes_attribute_self_time() {
        enable();
        {
            let _outer = scope(Subsystem::Scheduler);
            busy(2_000);
            {
                let _inner = scope(Subsystem::Prefix);
                busy(2_000);
            }
            busy(2_000);
        }
        let rep = take_report();
        assert!(!is_enabled());
        let get = |name: &str| {
            rep.subsystems
                .iter()
                .find(|s| s.name == name)
                .expect(name)
                .clone()
        };
        let sched = get("scheduler");
        let prefix = get("prefix");
        assert_eq!(sched.calls, 1);
        assert_eq!(prefix.calls, 1);
        assert!(sched.self_s > 0.0 && prefix.self_s > 0.0);
        // Exclusive accounting: buckets sum to ≤ the measured span.
        assert!(
            rep.covered_s <= rep.total_s * 1.01,
            "covered {} total {}",
            rep.covered_s,
            rep.total_s
        );
        // A tight loop of scoped work should be almost fully covered.
        assert!(rep.coverage > 0.5, "coverage {}", rep.coverage);
    }

    #[test]
    fn event_tallies_sum() {
        enable();
        count_event(EventClass::Arrival);
        count_event(EventClass::Arrival);
        count_event(EventClass::StrictStep);
        let rep = take_report();
        assert_eq!(rep.event_total(), 3);
        assert_eq!(rep.events.len(), 2);
    }

    #[test]
    fn report_json_shape() {
        enable();
        {
            let _p = scope(Subsystem::HeapPop);
        }
        count_event(EventClass::TransferChunk);
        let j = take_report().to_json();
        assert!(j.get("total_s").as_f64().is_some());
        assert!(j.get("subsystems").get("heap_pop").get("calls").as_f64()
            == Some(1.0));
        assert_eq!(
            j.get("event_counts").get("transfer_chunk").as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn fnv_is_stable() {
        // Pinned values: the config hash must be comparable across runs
        // and crate versions.
        assert_eq!(fnv1a64(""), 0xcbf29ce484222325);
        assert_eq!(config_hash("abc"), config_hash("abc"));
        assert_ne!(config_hash("abc"), config_hash("abd"));
        assert_eq!(config_hash("abc").len(), 16);
    }

    #[test]
    fn meta_fields() {
        let m = meta_json(7, "cfg", 1.5);
        assert_eq!(m.get("seed").as_u64(), Some(7));
        assert_eq!(m.get("wall_s").as_f64(), Some(1.5));
        assert_eq!(m.get("version").as_str(), Some(env!("CARGO_PKG_VERSION")));
        assert_eq!(m.get("config_hash").as_str().unwrap().len(), 16);
    }

    #[test]
    fn peak_rss_on_linux() {
        // Linux CI/dev boxes have procfs; elsewhere 0 is the contract.
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            assert!(rss > 0, "VmHWM should be readable on linux");
        }
    }

    /// Spin for roughly `iters` iterations of real work so scopes have
    /// measurable width without sleeping.
    fn busy(iters: u64) {
        let mut x = 0u64;
        for i in 0..iters {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(x);
    }
}
