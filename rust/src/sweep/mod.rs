//! Evaluation sweeps — the §5.2 experimental methodology as a library:
//!
//! 1. **Online capacity calibration**: find the traffic scaling at which the
//!    pure-online system *just* meets its SLO at the traffic peak ("the
//!    resource utilization limit for a pure online service scenario").
//! 2. **Offline load sweep**: from zero, increase uniform-QPS offline load
//!    and measure the online SLO violation rate at each level.
//! 3. **Max effective offline throughput**: the offline throughput just
//!    before the violation rate exceeds the threshold (3%).
//!
//! Used by `bench_fig6_colocation`, `bench_ablation`, and the paper-vs-ours
//! tables in EXPERIMENTS.md.

use crate::config::{FaultSpec, FleetSpec, ServingConfig};
use crate::coordinator::{Ablation, Policy};
use crate::fleet::{simulate_fleet, FleetConfig, FleetResult};
use crate::sim::{simulate, SimConfig, SimResult};
use crate::trace::datasets::DatasetProfile;
use crate::trace::generator::{
    offline_trace_with_prefix, online_trace, PrefixProfile,
};
use crate::trace::Trace;
use crate::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One point of an offline-load sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub offline_qps: f64,
    pub violation_rate: f64,
    pub offline_token_throughput: f64,
    pub ttft_p99: f64,
    pub tpot_p99: f64,
    pub migrations: u64,
    pub evictions: u64,
    /// Prefix-cache token-weighted hit rate at this load level (0 when the
    /// cache is off or the trace declares no shared prefixes) — lets a
    /// sweep plot SLO attainment vs load with and without caching.
    pub prefix_hit_rate: f64,
}

/// Sweep settings.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub duration_s: f64,
    pub seed: u64,
    pub ablation: Ablation,
    /// Shared-prefix structure of the swept offline workload (§3.7) —
    /// [`PrefixProfile::None`] reproduces the cold pre-cache sweeps; a
    /// sharing profile makes `SweepPoint::prefix_hit_rate` meaningful so
    /// attainment-vs-load can be compared with and without caching.
    pub offline_prefix: PrefixProfile,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            duration_s: 1800.0,
            seed: 42,
            ablation: Ablation::full(),
            offline_prefix: PrefixProfile::None,
        }
    }
}

fn sim_once(
    serving: &ServingConfig,
    policy: Policy,
    trace: &Trace,
    sweep: &SweepConfig,
) -> SimResult {
    let mut cfg = SimConfig::new(serving.clone(), policy);
    cfg.seed = sweep.seed;
    cfg.ablation = sweep.ablation;
    simulate(trace, &cfg)
}

/// Find the maximum pure-online arrival rate (req/s, pre-fluctuation base
/// rate) that keeps the violation rate at or under the SLO threshold.
/// This is the paper's "traffic scaling factor such that the system can
/// just meet the online traffic peak" (§5.2). Bisection over the base rate.
pub fn find_online_capacity(
    serving: &ServingConfig,
    dataset: &DatasetProfile,
    sweep: &SweepConfig,
) -> f64 {
    // "Just meet the online traffic peak without SLO violations" (§5.2):
    // calibrate to (near-)zero violations, not to the 3% threshold edge —
    // the threshold is the *failure* criterion for the offline sweep.
    let threshold = (serving.slo.violation_threshold / 6.0).max(0.005);
    let meets = |rate: f64| -> bool {
        if rate <= 0.0 {
            return true;
        }
        let trace =
            online_trace(dataset.clone(), rate, sweep.duration_s, sweep.seed);
        if trace.is_empty() {
            return true;
        }
        let res = sim_once(serving, Policy::Ooco, &trace, sweep);
        res.report.online_violation_rate <= threshold
    };

    // Exponential search for an upper bound, then bisection.
    let mut lo = 0.0f64;
    let mut hi = 0.25f64;
    while meets(hi) {
        lo = hi;
        hi *= 2.0;
        if hi > 512.0 {
            return lo; // absurdly high capacity; stop
        }
    }
    for _ in 0..7 {
        let mid = 0.5 * (lo + hi);
        if meets(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Evaluate one offline-load level: merge the offline trace for `qps`
/// into the shared online trace and run one seeded sim. Both the
/// sequential and the parallel sweep drivers go through this single
/// helper, which is what makes `--jobs N` output bit-identical to
/// `--jobs 1`: a point's result depends only on its own inputs, never on
/// which worker ran it or in what order.
fn sweep_point(
    serving: &ServingConfig,
    policy: Policy,
    online: &Trace,
    offline_ds: &DatasetProfile,
    qps: f64,
    sweep: &SweepConfig,
) -> SweepPoint {
    let trace = if qps > 0.0 {
        online.clone().merge(offline_trace_with_prefix(
            offline_ds.clone(),
            qps,
            sweep.duration_s,
            sweep.offline_prefix,
            sweep.seed + 1,
        ))
    } else {
        online.clone()
    };
    let res = sim_once(serving, policy, &trace, sweep);
    SweepPoint {
        offline_qps: qps,
        violation_rate: res.report.online_violation_rate,
        offline_token_throughput: res.report.offline_token_throughput,
        ttft_p99: res.report.ttft.p99,
        tpot_p99: res.report.tpot.p99,
        migrations: res.migrations,
        evictions: res.evictions,
        prefix_hit_rate: res.prefix.hit_rate,
    }
}

/// Sweep offline QPS for one policy at a fixed online rate.
pub fn offline_sweep(
    serving: &ServingConfig,
    policy: Policy,
    online_ds: &DatasetProfile,
    online_rate: f64,
    offline_ds: &DatasetProfile,
    qps_levels: &[f64],
    sweep: &SweepConfig,
) -> Vec<SweepPoint> {
    let online = online_trace(
        online_ds.clone(),
        online_rate,
        sweep.duration_s,
        sweep.seed,
    );
    qps_levels
        .iter()
        .map(|&qps| {
            sweep_point(serving, policy, &online, offline_ds, qps, sweep)
        })
        .collect()
}

/// [`offline_sweep`] fanned out over `jobs` worker threads. Each load
/// level is an independent seeded simulation (the simulator and the
/// self-profiler keep no cross-thread state — obs is thread-local), so
/// workers pull levels from a shared atomic cursor and the results are
/// merged back into load-level order. Output is element-identical to the
/// sequential driver for any `jobs`; `jobs <= 1` takes the sequential
/// path outright.
#[allow(clippy::too_many_arguments)]
pub fn offline_sweep_parallel(
    serving: &ServingConfig,
    policy: Policy,
    online_ds: &DatasetProfile,
    online_rate: f64,
    offline_ds: &DatasetProfile,
    qps_levels: &[f64],
    sweep: &SweepConfig,
    jobs: usize,
) -> Vec<SweepPoint> {
    if jobs <= 1 || qps_levels.len() <= 1 {
        return offline_sweep(
            serving,
            policy,
            online_ds,
            online_rate,
            offline_ds,
            qps_levels,
            sweep,
        );
    }
    let online = online_trace(
        online_ds.clone(),
        online_rate,
        sweep.duration_s,
        sweep.seed,
    );
    let next = AtomicUsize::new(0);
    let workers = jobs.min(qps_levels.len());
    let mut slots: Vec<Option<SweepPoint>> = vec![None; qps_levels.len()];
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let online = &online;
                s.spawn(move || {
                    let mut mine: Vec<(usize, SweepPoint)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= qps_levels.len() {
                            break;
                        }
                        mine.push((
                            i,
                            sweep_point(
                                serving,
                                policy,
                                online,
                                offline_ds,
                                qps_levels[i],
                                sweep,
                            ),
                        ));
                    }
                    mine
                })
            })
            .collect();
        for h in handles {
            for (i, p) in h.join().expect("sweep worker panicked") {
                slots[i] = Some(p);
            }
        }
    });
    slots
        .into_iter()
        .map(|p| p.expect("every sweep point computed"))
        .collect()
}

impl SweepPoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offline_qps", Json::Num(self.offline_qps)),
            ("violation_rate", Json::Num(self.violation_rate)),
            ("slo_attainment", Json::Num(1.0 - self.violation_rate)),
            (
                "offline_token_throughput",
                Json::Num(self.offline_token_throughput),
            ),
            ("ttft_p99", Json::Num(self.ttft_p99)),
            ("tpot_p99", Json::Num(self.tpot_p99)),
            ("migrations", Json::Num(self.migrations as f64)),
            ("evictions", Json::Num(self.evictions as f64)),
            ("prefix_hit_rate", Json::Num(self.prefix_hit_rate)),
        ])
    }
}

/// Machine-readable SLO-attainment-vs-load curve (`util::json`): one entry
/// per swept load level, so pool-manager experiments are comparable across
/// runs with `jq`-style tooling instead of scraping summary lines.
pub fn curve_to_json(label: &str, points: &[SweepPoint]) -> Json {
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        (
            "points",
            Json::Arr(points.iter().map(SweepPoint::to_json).collect()),
        ),
    ])
}

/// Failover recovery comparison (DESIGN.md §3.9): run the same trace under
/// the same crash schedule twice — once with the schedule's advance notice
/// intact (KV *restreams* to staging / live instances before the crash)
/// and once with every notice stripped (lost KV is *recomputed* from
/// scratch) — and return `(restream, recompute)`. Everything else (seed,
/// topology, ablation) is held identical, so the delta isolates the
/// recoverable-evacuation path.
pub fn failover_compare(
    serving: &ServingConfig,
    policy: Policy,
    trace: &Trace,
    fleet: FleetSpec,
    fault: &FaultSpec,
    sweep: &SweepConfig,
) -> (FleetResult, FleetResult) {
    let run = |fault: FaultSpec| {
        let mut sim = SimConfig::new(serving.clone(), policy);
        sim.seed = sweep.seed;
        sim.ablation = sweep.ablation;
        simulate_fleet(trace, &FleetConfig { sim, fleet, fault })
    };
    let mut recompute = fault.clone();
    for c in &mut recompute.crashes {
        c.notice_s = 0.0;
    }
    if let Some(m) = &mut recompute.mtbf {
        m.notice_s = 0.0;
    }
    (run(fault.clone()), run(recompute))
}

/// The paper's headline metric: the offline throughput just before the
/// online violation rate exceeds `threshold` (0 if even the first offline
/// level violates).
pub fn max_effective_offline(points: &[SweepPoint], threshold: f64) -> f64 {
    let mut best = 0.0f64;
    for p in points {
        if p.violation_rate <= threshold {
            best = best.max(p.offline_token_throughput);
        } else {
            break; // paper semantics: the level just before the violation
        }
    }
    best
}

/// Geometric QPS grid from `lo` to `hi` with `n` points (plus a zero point).
pub fn qps_grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(lo > 0.0 && hi > lo && n >= 2);
    let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
    let mut out = Vec::with_capacity(n);
    let mut q = lo;
    for _ in 0..n {
        out.push(q);
        q *= ratio;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_sweep() -> SweepConfig {
        SweepConfig {
            duration_s: 420.0,
            seed: 7,
            ablation: Ablation::full(),
            offline_prefix: PrefixProfile::None,
        }
    }

    #[test]
    fn qps_grid_shape() {
        let g = qps_grid(1.0, 16.0, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 1.0).abs() < 1e-9);
        assert!((g[4] - 16.0).abs() < 1e-6);
        assert!((g[2] - 4.0).abs() < 1e-6); // geometric midpoint
    }

    #[test]
    fn max_effective_offline_stops_at_first_violation() {
        let mk = |q: f64, v: f64, t: f64| SweepPoint {
            offline_qps: q,
            violation_rate: v,
            offline_token_throughput: t,
            ttft_p99: 0.0,
            tpot_p99: 0.0,
            migrations: 0,
            evictions: 0,
            prefix_hit_rate: 0.25,
        };
        let pts = vec![
            mk(1.0, 0.0, 100.0),
            mk(2.0, 0.01, 220.0),
            mk(4.0, 0.08, 400.0), // violates
            mk(8.0, 0.01, 800.0), // would pass but is beyond the break
        ];
        assert_eq!(max_effective_offline(&pts, 0.03), 220.0);
        assert_eq!(max_effective_offline(&pts[2..], 0.03), 0.0);
        assert_eq!(max_effective_offline(&[], 0.03), 0.0);
        // Machine-readable curve: label + per-point SLO attainment.
        let j = curve_to_json("ooco", &pts);
        assert_eq!(j.get("label").as_str(), Some("ooco"));
        let att = j.get("points").idx(2).get("slo_attainment").as_f64();
        assert!((att.unwrap() - 0.92).abs() < 1e-12);
        assert_eq!(
            j.get("points").idx(0).get("prefix_hit_rate").as_f64(),
            Some(0.25)
        );
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn capacity_calibration_finds_a_knee() {
        let serving = ServingConfig::preset_7b();
        let ds = DatasetProfile::azure_conv();
        let cap = find_online_capacity(&serving, &ds, &quick_sweep());
        assert!(cap > 0.1, "capacity {cap} too low");
        // And the found rate indeed meets SLO while 4x of it does not.
        let sweep = quick_sweep();
        let t_ok =
            online_trace(ds.clone(), cap * 0.9, sweep.duration_s, sweep.seed);
        let ok = sim_once(&serving, Policy::Ooco, &t_ok, &sweep);
        assert!(ok.report.online_violation_rate <= 0.05, "at-cap violates");
        let t_over = online_trace(ds, cap * 4.0, sweep.duration_s, sweep.seed);
        let over = sim_once(&serving, Policy::Ooco, &t_over, &sweep);
        assert!(
            over.report.online_violation_rate > 0.03,
            "4x capacity should violate ({})",
            over.report.online_violation_rate
        );
    }

    #[test]
    fn shared_prefix_sweep_reports_nonzero_hit_rate() {
        // The prefix_hit_rate column must be producible end to end: a
        // sweep over a sharing profile yields hits; the cold profile
        // stays at zero.
        let serving = ServingConfig::preset_7b();
        let mut sweep = quick_sweep();
        sweep.duration_s = 240.0;
        sweep.offline_prefix =
            PrefixProfile::SharedSystem { prefix_len: 1000 };
        let pts = offline_sweep(
            &serving,
            Policy::Ooco,
            &DatasetProfile::azure_conv(),
            0.3,
            &DatasetProfile::ooc_offline(),
            &[2.0],
            &sweep,
        );
        assert!(
            pts[0].prefix_hit_rate > 0.0,
            "sharing profile must produce cache hits: {:?}",
            pts[0]
        );
        sweep.offline_prefix = PrefixProfile::None;
        let cold = offline_sweep(
            &serving,
            Policy::Ooco,
            &DatasetProfile::azure_conv(),
            0.3,
            &DatasetProfile::ooc_offline(),
            &[2.0],
            &sweep,
        );
        assert_eq!(cold[0].prefix_hit_rate, 0.0);
    }

    #[test]
    fn parallel_sweep_matches_sequential() {
        let serving = ServingConfig::preset_7b();
        let mut sweep = quick_sweep();
        sweep.duration_s = 180.0;
        let levels = [0.0, 1.0, 4.0];
        let run = |jobs: usize| {
            offline_sweep_parallel(
                &serving,
                Policy::Ooco,
                &DatasetProfile::azure_conv(),
                0.3,
                &DatasetProfile::ooc_offline(),
                &levels,
                &sweep,
                jobs,
            )
        };
        let seq = run(1);
        let par = run(3);
        // Byte-identical merged curves: worker scheduling must never
        // leak into the results.
        assert_eq!(
            curve_to_json("curve", &seq).to_string(),
            curve_to_json("curve", &par).to_string()
        );
    }

    #[test]
    fn sweep_monotone_offline_throughput_before_violation() {
        let serving = ServingConfig::preset_7b();
        let sweep = quick_sweep();
        let pts = offline_sweep(
            &serving,
            Policy::Ooco,
            &DatasetProfile::azure_conv(),
            0.4,
            &DatasetProfile::ooc_offline(),
            &[0.5, 2.0, 8.0],
            &sweep,
        );
        assert_eq!(pts.len(), 3);
        assert!(pts[1].offline_token_throughput > pts[0].offline_token_throughput);
        assert!(pts[2].offline_token_throughput >= pts[1].offline_token_throughput);
    }
}
