//! Property-based tests over coordinator invariants (routing, batching,
//! eviction, migration) using the in-repo `testutil::forall` harness.
//!
//! Decision functions are imported through the `scheduler` surface — the
//! single public entry to the §3.4 logic since the SchedulerCore redesign.

use ooco::config::{HardwareProfile, ModelSpec, SloSpec};
use ooco::coordinator::Router;
use ooco::scheduler::{
    migration_decision, pick_migration_candidates, select_decode_batch,
    select_evictions, Candidate, LengthPref,
};
use ooco::perfmodel::{BatchStats, Bottleneck, PerfModel};
use ooco::prop_assert;
use ooco::testutil::forall;
use ooco::util::rng::Pcg;

fn pm() -> PerfModel {
    PerfModel::new(ModelSpec::qwen2_5_7b(), HardwareProfile::ascend_910c())
}

#[test]
fn mix_decode_never_violates_bound_when_online_fits() {
    let pm = pm();
    forall(60, |r| {
        let n_on = r.below(8);
        let online: Vec<Candidate> =
            (0..n_on).map(|i| (i as u64, r.below(2500) + 1)).collect();
        let n_off = r.below(80);
        let offline: Vec<Candidate> = (0..n_off)
            .map(|i| (100 + i as u64, r.below(2500) + 1))
            .collect();
        let bound = 0.03 + r.f64() * 0.08;
        let sel = select_decode_batch(&pm, &online, &offline, bound, 8, r);
        if !sel.online_over_slo {
            prop_assert!(
                sel.predicted_latency <= bound + 1e-12,
                "bound {bound} violated: {}",
                sel.predicted_latency
            );
        }
        // Chosen offline ids must come from the candidate set, once each.
        let mut seen = std::collections::HashSet::new();
        for id in &sel.offline {
            prop_assert!(
                offline.iter().any(|c| c.0 == *id),
                "unknown id {id}"
            );
            prop_assert!(seen.insert(*id), "duplicate {id}");
        }
        Ok(())
    });
}

#[test]
fn mix_decode_maximal_under_uniform_lengths() {
    // With equal-length candidates the selection must be maximal: either
    // everything is admitted or adding one more would break the bound.
    let pm = pm();
    forall(40, |r| {
        let len = r.below(2000) + 50;
        let n = r.below(100) + 1;
        let offline: Vec<Candidate> =
            (0..n).map(|i| (i as u64, len)).collect();
        let bound = 0.02 + r.f64() * 0.08;
        let sel = select_decode_batch(&pm, &[], &offline, bound, 8, r);
        if sel.offline.len() < n {
            let bigger = sel.stats.with(len);
            prop_assert!(
                pm.decode_latency(bigger) > bound,
                "not maximal: {} chosen of {n}",
                sel.offline.len()
            );
        }
        Ok(())
    });
}

#[test]
fn eviction_order_respects_bottleneck() {
    let pm = pm();
    forall(40, |r| {
        let n = r.below(20) + 2;
        let victims: Vec<Candidate> = (0..n)
            .map(|i| (i as u64, r.below(5000) + 1))
            .collect();
        let total: usize = victims.iter().map(|c| c.1).sum();
        let needed = r.below(total.max(2) - 1) + 1;

        // Compute-bound: chosen victims must dominate the unchosen by
        // length (longest-first policy).
        let chosen =
            select_evictions(&pm, &victims, needed, Bottleneck::Compute, true);
        let chosen_lens: Vec<usize> = chosen
            .iter()
            .map(|id| victims.iter().find(|c| c.0 == *id).unwrap().1)
            .collect();
        let min_chosen = chosen_lens.iter().min().copied().unwrap_or(0);
        for c in &victims {
            prop_assert!(
                c.1 <= min_chosen || chosen.contains(&c.0),
                "longer victim {} (len {}) skipped; min chosen {}",
                c.0,
                c.1,
                min_chosen
            );
        }
        Ok(())
    });
}

#[test]
fn migration_pref_consistent_with_predictor() {
    let pm = pm();
    let slo = SloSpec::default();
    forall(60, |r| {
        let n = r.below(400) + 1;
        let mean_len = r.below(2000) + 50;
        let batch = BatchStats::new(n, n * mean_len);
        let pref = migration_decision(&pm, batch, true, slo.tpot, 0.1);
        let bound = slo.tpot * 0.9;
        match pref {
            LengthPref::None => {
                let over = pm.decode_latency(batch) >= bound;
                let nothing_fits = {
                    let b = batch.with(1);
                    pm.decode_latency(b) > bound
                        || pm.memory_utilization(b) > 1.0
                };
                prop_assert!(over || nothing_fits, "None without reason");
            }
            LengthPref::LongestUpTo { max_len } => {
                prop_assert!(max_len >= 1, "degenerate max_len");
                let b = batch.with(max_len);
                prop_assert!(
                    pm.decode_latency(b) <= bound + 1e-9,
                    "advertised length breaks bound"
                );
                prop_assert!(
                    pm.memory_utilization(b) <= 1.0 + 1e-9,
                    "advertised length breaks capacity"
                );
            }
            LengthPref::Shortest => {
                prop_assert!(batch.size < pm.bs_sat(), "Shortest above sat");
            }
        }
        Ok(())
    });
}

#[test]
fn migration_candidates_subset_and_bounded() {
    forall(60, |r| {
        let n = r.below(50);
        let cands: Vec<Candidate> = (0..n)
            .map(|i| (i as u64, r.below(4000) + 1))
            .collect();
        let max_count = r.below(10);
        let pref = match r.below(3) {
            0 => LengthPref::None,
            1 => LengthPref::Shortest,
            _ => LengthPref::LongestUpTo {
                max_len: r.below(4000) + 1,
            },
        };
        let picked = pick_migration_candidates(pref, &cands, max_count);
        prop_assert!(picked.len() <= max_count, "over max_count");
        if pref == LengthPref::None {
            prop_assert!(picked.is_empty(), "None must pick nothing");
        }
        for id in &picked {
            prop_assert!(cands.iter().any(|c| c.0 == *id), "foreign id");
        }
        Ok(())
    });
}

#[test]
fn router_load_conservation() {
    forall(30, |r| {
        let n_relaxed = r.below(4) + 1;
        let n_strict = r.below(4) + 1;
        let mut router = Router::new(n_relaxed, n_strict);
        let mut outstanding: Vec<(usize, usize)> = Vec::new();
        for _ in 0..200 {
            if r.chance(0.6) || outstanding.is_empty() {
                let tokens = r.below(4000) + 1;
                let inst = router.route_prefill(tokens);
                prop_assert!(inst < n_relaxed, "bad instance");
                outstanding.push((inst, tokens));
            } else {
                let idx = r.below(outstanding.len());
                let (inst, tokens) = outstanding.swap_remove(idx);
                router.prefill_done(inst, tokens);
            }
        }
        for (inst, tokens) in outstanding {
            router.prefill_done(inst, tokens);
        }
        prop_assert!(router.route_prefill(1) < n_relaxed, "post-drain route");
        Ok(())
    });
}

#[test]
fn selection_deterministic_given_rng_seed() {
    let pm = pm();
    let online: Vec<Candidate> = (0..5).map(|i| (i, 800)).collect();
    let offline: Vec<Candidate> = (0..50)
        .map(|i| (100 + i, 500 + (i as usize * 37) % 1500))
        .collect();
    let mut r1 = Pcg::seeded(9);
    let mut r2 = Pcg::seeded(9);
    let a = select_decode_batch(&pm, &online, &offline, 0.06, 8, &mut r1);
    let b = select_decode_batch(&pm, &online, &offline, 0.06, 8, &mut r2);
    assert_eq!(a.offline, b.offline);
    assert_eq!(a.stats, b.stats);
}

#[test]
fn sim_seed_sensitivity_is_bounded() {
    // Different seeds shift the trace but the policy ordering (OOCO >=
    // online-priority offline throughput at saturation) must be stable.
    use ooco::config::ServingConfig;
    use ooco::coordinator::Policy;
    use ooco::sim::{simulate, SimConfig};
    use ooco::trace::datasets::DatasetProfile;
    use ooco::trace::generator::{offline_trace, online_trace};

    for seed in [1u64, 7, 23] {
        let online =
            online_trace(DatasetProfile::azure_conv(), 0.5, 600.0, seed);
        let offline =
            offline_trace(DatasetProfile::ooc_offline(), 20.0, 600.0, seed + 50);
        let trace = online.merge(offline);
        let mut results = Vec::new();
        for policy in [Policy::OnlinePriority, Policy::Ooco] {
            let mut cfg = SimConfig::new(ServingConfig::preset_7b(), policy);
            cfg.seed = seed;
            results.push(simulate(&trace, &cfg));
        }
        assert!(
            results[1].report.offline_token_throughput
                >= results[0].report.offline_token_throughput,
            "seed {seed}: ooco {} < op {}",
            results[1].report.offline_token_throughput,
            results[0].report.offline_token_throughput
        );
    }
}
