//! End-to-end tests over the real PJRT engine (requires `make artifacts`).
//!
//! These prove the three layers compose: Pallas kernels (L1) lowered into
//! the JAX model (L2), AOT-compiled to HLO, executed by the rust
//! coordinator (L3) with Algorithm 2 batching on calibrated predictions.

use std::path::PathBuf;

use ooco::coordinator::Policy;
use ooco::engine::{calibrate_runtime, serve_trace_with_runtime, EngineConfig};
use ooco::perfmodel::mean_abs_rel_error;
use ooco::request::{Class, Request};
use ooco::runtime::{DecodeEntry, Runtime};
use ooco::trace::Trace;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Shared runtime: compiling all buckets takes seconds, do it once. The
/// xla handles are raw pointers (not Sync), so access is serialized behind
/// a mutex; the wrapper's Send/Sync is sound because the mutex guarantees
/// exclusive use and the PJRT CPU client has no thread affinity.
struct SharedRt(Option<Runtime>);
unsafe impl Send for SharedRt {}
unsafe impl Sync for SharedRt {}

fn with_runtime<F: FnOnce(&Runtime)>(f: F) {
    use std::sync::{Mutex, OnceLock};
    static RT: OnceLock<Mutex<SharedRt>> = OnceLock::new();
    let cell = RT.get_or_init(|| {
        Mutex::new(SharedRt(artifacts().map(|d| Runtime::load(&d).unwrap())))
    });
    let guard = cell.lock().unwrap();
    match &guard.0 {
        Some(rt) => f(rt),
        None => eprintln!("skipping: artifacts not built"),
    }
}

#[test]
fn prefill_deterministic_and_shaped() {
    with_runtime(|rt| {
        let toks: Vec<i32> = (0..50).map(|i| (i * 7) % 512).collect();
        let a = rt.prefill(&toks).unwrap();
        let b = rt.prefill(&toks).unwrap();
        assert_eq!(a.logits.len(), rt.manifest.vocab);
        assert_eq!(a.kv.k.len(), rt.kv_elems());
        assert_eq!(a.logits, b.logits, "prefill must be deterministic");
        assert!(a.logits.iter().all(|x| x.is_finite()));
        let mean_abs: f32 =
            a.logits.iter().map(|x| x.abs()).sum::<f32>() / a.logits.len() as f32;
        assert!(mean_abs > 0.01, "logits look zeroed: {mean_abs}");
    });
}

#[test]
fn bucket_selection_rounds_up() {
    with_runtime(|rt| {
        assert_eq!(rt.prefill_bucket(1).unwrap(), 64);
        assert_eq!(rt.prefill_bucket(64).unwrap(), 64);
        assert_eq!(rt.prefill_bucket(65).unwrap(), 128);
        assert!(rt.prefill_bucket(100_000).is_err());
        assert_eq!(rt.decode_bucket(3).unwrap(), 4);
        assert_eq!(rt.decode_bucket(16).unwrap(), 16);
        assert!(rt.decode_bucket(17).is_err());
    });
}

#[test]
fn decode_matches_prefill_consistency() {
    with_runtime(|rt| {
        // prefill(L+1) logits == prefill(L) + decode step (same as the
        // python test, but through the full rust path).
        let full: Vec<i32> = (0..33).map(|i| (i * 13) % 512).collect();
        let want = rt.prefill(&full).unwrap().logits;

        let prefix = &full[..32];
        let out = rt.prefill(prefix).unwrap();
        let mut kv = out.kv;
        let mut entries = [DecodeEntry {
            token: full[32],
            position: 32,
            kv: &mut kv,
        }];
        let got = rt.decode(&mut entries).unwrap();
        let max_err = want
            .iter()
            .zip(&got[0])
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-3, "decode/prefill mismatch {max_err}");
    });
}

#[test]
fn batched_decode_matches_single() {
    with_runtime(|rt| {
        let t1: Vec<i32> = (0..20).map(|i| (i * 3) % 512).collect();
        let t2: Vec<i32> = (0..40).map(|i| (i * 5) % 512).collect();
        let o1 = rt.prefill(&t1).unwrap();
        let o2 = rt.prefill(&t2).unwrap();

        let mut kv1 = o1.kv.clone();
        let single = {
            let mut e = [DecodeEntry {
                token: 7,
                position: 20,
                kv: &mut kv1,
            }];
            rt.decode(&mut e).unwrap()[0].clone()
        };

        let mut kv1b = o1.kv.clone();
        let mut kv2 = o2.kv.clone();
        let batched = {
            let mut es = [
                DecodeEntry {
                    token: 7,
                    position: 20,
                    kv: &mut kv1b,
                },
                DecodeEntry {
                    token: 9,
                    position: 40,
                    kv: &mut kv2,
                },
            ];
            rt.decode(&mut es).unwrap()
        };
        let max_err = single
            .iter()
            .zip(&batched[0])
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-4, "batch independence broken: {max_err}");
        let kv_err = kv1
            .k
            .iter()
            .zip(&kv1b.k)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(kv_err < 1e-5, "kv mismatch {kv_err}");
    });
}

#[test]
fn multi_step_generation_progresses() {
    with_runtime(|rt| {
        let toks: Vec<i32> = (0..16).map(|i| (i * 17) % 512).collect();
        let out = rt.prefill(&toks).unwrap();
        let mut kv = out.kv;
        let mut token = 3i32;
        let mut pos = 16i32;
        let mut seen = Vec::new();
        for _ in 0..8 {
            let mut e = [DecodeEntry {
                token,
                position: pos,
                kv: &mut kv,
            }];
            let lg = rt.decode(&mut e).unwrap();
            token = lg[0]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            seen.push(token);
            pos += 1;
        }
        assert_eq!(seen.len(), 8);
        assert!(seen.iter().all(|&t| (t as usize) < rt.manifest.vocab));
    });
}

#[test]
fn calibrated_perf_model_is_accurate() {
    with_runtime(|rt| {
        let (pm, samples) = calibrate_runtime(rt).unwrap();
        let err = mean_abs_rel_error(&pm.model, &pm.hw, &samples);
        // The paper reports ~5% on the 910c; CPU timing jitter is larger,
        // accept a loose bound here (the bench reports the exact number).
        assert!(err < 0.60, "calibration error {err}");
        assert!(!samples.is_empty());
    });
}

#[test]
fn elastic_repartition_runs_on_the_real_engine() {
    // The drain/flip/warm machinery on the wall-clock substrate: start
    // with an overprovisioned strict pool (1 relaxed / 2 strict) under a
    // light mixed load; the Periodic planner wants 1 strict instance, so
    // the engine must drain the strict tail, flip it, run its warm step
    // (`StepKind::Warm` executes as a no-op model step), and finish with
    // a 2 relaxed / 1 strict cluster — all on real PJRT execution.
    with_runtime(|rt| {
        let mut reqs = Vec::new();
        for i in 0..8u64 {
            reqs.push(Request::new(
                i,
                Class::Online,
                0.4 * i as f64,
                40 + (i as usize) * 11,
                6,
            ));
        }
        for i in 8..12u64 {
            reqs.push(Request::new(
                i,
                Class::Offline,
                0.5 * (i - 8) as f64,
                90 + (i as usize) * 5,
                8,
            ));
        }
        let trace = Trace::new(reqs);
        let cfg = EngineConfig {
            policy: Policy::Ooco,
            cluster: ooco::config::ClusterSpec {
                relaxed_instances: 1,
                strict_instances: 2,
            },
            pool: ooco::config::PoolPolicy::Periodic {
                epoch_s: 1.0,
                headroom: 0.15,
            },
            time_scale: 10.0,
            max_output: 8,
            ..Default::default()
        };
        let out = serve_trace_with_runtime(rt, &trace, &cfg).unwrap();
        assert_eq!(out.report.online_finished, 8, "{}", out.report.summary_line());
        assert!(out.pool.plans >= 1, "{}", out.pool.summary_line());
        assert!(
            out.pool.flips >= 1,
            "overprovisioned strict pool must shrink on the engine: {}",
            out.pool.summary_line()
        );
        assert_eq!(out.pool.final_relaxed, 2, "{}", out.pool.summary_line());
        assert_eq!(out.pool.final_strict, 1, "{}", out.pool.summary_line());
        assert_eq!(out.pool.transition_s.count as u64, out.pool.flips);
    });
}

#[test]
fn engine_accounts_shared_prefixes() {
    // A shared-prefix offline family on the real substrate: the core
    // shares and prices cached blocks (the engine still recomputes them —
    // DESIGN.md §3.7 divergence), so the outcome's prefix report must show
    // hits and savings. Arrivals are spaced well past the tiny model's
    // prefill time so each request finds its predecessor's chain
    // registered.
    with_runtime(|rt| {
        let fam = 0xfeed_u64;
        let reqs: Vec<Request> = (0..6u64)
            .map(|i| {
                Request::new(i, Class::Offline, 2.0 * i as f64, 96, 4)
                    .with_prefix(fam, 64)
            })
            .collect();
        let trace = Trace::new(reqs);
        let cfg = EngineConfig {
            policy: Policy::Ooco,
            time_scale: 10.0,
            max_output: 4,
            ..Default::default()
        };
        let out = serve_trace_with_runtime(rt, &trace, &cfg).unwrap();
        assert_eq!(
            out.report.offline_finished,
            6,
            "{}",
            out.report.summary_line()
        );
        assert!(out.prefix.enabled);
        assert!(
            out.prefix.hits >= 1,
            "later family members must hit the chain: {}",
            out.prefix.summary_line()
        );
        assert!(out.prefix.prefill_tokens_saved > 0);
    });
}

#[test]
fn serve_small_mixed_trace_end_to_end() {
    with_runtime(|rt| {
        let mut reqs = Vec::new();
        for i in 0..6u64 {
            reqs.push(Request::new(
                i,
                Class::Online,
                0.05 * i as f64,
                40 + (i as usize) * 13,
                6,
            ));
        }
        for i in 6..12u64 {
            reqs.push(Request::new(
                i,
                Class::Offline,
                0.03 * i as f64,
                80 + (i as usize) * 7,
                8,
            ));
        }
        let trace = Trace::new(reqs);
        let cfg = EngineConfig {
            policy: Policy::Ooco,
            time_scale: 10.0,
            max_output: 8,
            ..Default::default()
        };
        let out = serve_trace_with_runtime(rt, &trace, &cfg).unwrap();
        assert_eq!(out.report.online_total, 6);
        assert_eq!(out.report.offline_total, 6);
        assert_eq!(out.report.online_finished, 6, "{}", out.report.summary_line());
        assert_eq!(out.report.offline_finished, 6);
        assert!(out.prefills >= 12);
        assert!(out.strict_steps > 0);
        assert!(out.online_tokens >= 6 * 6);
        assert!(out.offline_tokens > 0);
    });
}
