//! Fleet-layer property tests (DESIGN.md §3.9):
//!
//! 1. **Degenerate-fleet differential**: a single-replica zero-fault fleet
//!    emits an action stream byte-identical to the single-cluster
//!    `VirtualExecutor` path — the fleet layer adds *nothing* until
//!    replicas or faults do.
//! 2. **No request silently lost**: across crash → recover cycles every
//!    unfinished request stays held by some scheduling structure of its
//!    assigned replica (`accounting_errors == 0`), and with enough drain
//!    every request finishes with full token conservation.
//! 3. **Seeded determinism**: two runs with the same seed — including
//!    stochastic MTBF fault sampling — produce byte-identical
//!    machine-readable output.
//! 4. **Fault-injection safety**: the last live instance of a pool is
//!    never killed; skipped faults are accounted.

use ooco::config::{FaultSpec, ServingConfig};
use ooco::coordinator::Policy;
use ooco::fleet::{simulate_fleet, simulate_fleet_traced, Fleet, FleetConfig};
use ooco::scheduler::{Executor, SchedulerCore, VirtualExecutor};
use ooco::sim::SimConfig;
use ooco::telemetry::TelemetryOpts;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;
use ooco::util::json::Json;

fn mixed_trace(duration: f64, seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.6, duration, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 1.5, duration, seed + 1);
    online.merge(offline)
}

fn fleet_cfg(serving: ServingConfig) -> FleetConfig {
    let mut sim = SimConfig::new(serving, Policy::Ooco);
    sim.seed = 11;
    FleetConfig::new(sim)
}

fn two_by_two() -> ServingConfig {
    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 2;
    serving.cluster.strict_instances = 2;
    serving
}

/// Acceptance criterion: with one replica and no faults, the fleet replays
/// the exact single-cluster schedule — same event ties, same clock, same
/// decisions — so its action stream matches `VirtualExecutor`'s.
#[test]
fn single_replica_zero_fault_fleet_matches_single_cluster() {
    let trace = mixed_trace(90.0, 42);
    let cfg = fleet_cfg(ServingConfig::preset_7b());

    let horizon = trace.duration() + cfg.sim.drain_s;
    let mut virt = VirtualExecutor::new(&trace, horizon);
    virt.log = Some(Vec::new());
    let mut core =
        SchedulerCore::new(trace.requests.clone(), cfg.sim.core());
    virt.run(&mut core).unwrap();

    let mut fleet = Fleet::new(&trace, &cfg);
    fleet.log = Some(Vec::new());
    let res = fleet.run(&trace);

    let single = virt.log.unwrap();
    let tagged = fleet.log.take().unwrap();
    assert!(!single.is_empty());
    assert!(
        tagged.iter().all(|(replica, _)| *replica == 0),
        "single-replica fleet routed off replica 0"
    );
    assert_eq!(
        single.len(),
        tagged.len(),
        "stream lengths differ ({} vs {})",
        single.len(),
        tagged.len()
    );
    for (i, (a, (_, b))) in single.iter().zip(&tagged).enumerate() {
        assert_eq!(a, b, "streams diverge at action {i}");
    }
    assert_eq!(res.fleet.crashes, 0);
    assert_eq!(res.fleet.steals, 0);
    assert_eq!(res.fleet.skipped_faults, 0);
    assert_eq!(res.fleet.accounting_errors, 0);
    assert!((res.fleet.availability - 1.0).abs() < 1e-12);
    // And the merged report sees the same per-request outcomes.
    let finished_single = core
        .cluster
        .requests
        .iter()
        .filter(|r| r.finished_at.is_some())
        .count();
    assert_eq!(
        res.report.online_finished + res.report.offline_finished,
        finished_single
    );
}

/// No request silently lost across a crash: the crash fires mid-run, its
/// KV losses re-route/requeue, and with a generous drain *every* request
/// still finishes with its full output — token conservation through the
/// fault.
#[test]
fn crash_recover_conserves_every_request() {
    let trace = mixed_trace(60.0, 7);
    let mut cfg = fleet_cfg(two_by_two());
    cfg.sim.drain_s = 3000.0;
    cfg.fault =
        "crash(at=20,pool=relaxed,inst=0,down=30); \
         crash(at=25,pool=strict,inst=1,down=30)"
            .parse()
            .unwrap();

    let mut fleet = Fleet::new(&trace, &cfg);
    let res = fleet.run(&trace);

    assert_eq!(res.fleet.crashes, 2, "both crashes must fire");
    assert_eq!(res.fleet.recoveries, 2, "both instances must recover");
    assert!(res.fleet.availability < 1.0);
    assert_eq!(res.fleet.accounting_errors, 0, "request lost to the crash");
    assert_eq!(
        res.report.online_finished, res.report.online_total,
        "online requests must all finish despite the crashes"
    );
    assert!(
        res.report.offline_finished as f64
            >= 0.9 * res.report.offline_total as f64,
        "offline finished {}/{}",
        res.report.offline_finished,
        res.report.offline_total
    );
    // Token conservation: each finished request generated exactly its
    // target output, crash evictions and recomputes notwithstanding —
    // and anything unfinished is still held (the accounting check above),
    // not dropped.
    let cluster = &fleet.replica(0).cluster;
    for r in &cluster.requests {
        if r.finished_at.is_some() {
            assert_eq!(
                r.generated, r.output_len,
                "request {} token count off",
                r.id
            );
        }
    }
}

/// Seeded determinism, stochastic faults included: the MTBF schedule is
/// pre-generated from a dedicated seeded stream, so two runs of the same
/// config produce byte-identical machine-readable output.
#[test]
fn same_seed_same_bytes_under_stochastic_faults() {
    let trace = mixed_trace(90.0, 13);
    let mut cfg = fleet_cfg(two_by_two());
    cfg.fleet.replicas = 2;
    cfg.fault = "mtbf(mean=120,mttr=25)".parse().unwrap();

    // Telemetry rides the same deterministic action stream: the Perfetto
    // buffer and the timeline/attribution JSON must be byte-identical
    // across same-seed runs too.
    let dump = |trace: &Trace, cfg: &FleetConfig| {
        let mut opts = TelemetryOpts::new(cfg.sim.serving.slo);
        opts.perfetto = true;
        let res = simulate_fleet_traced(trace, cfg, Some(opts));
        let tel = res.telemetry.expect("telemetry requested");
        Json::obj(vec![
            ("report", res.report.to_json()),
            ("fleet", res.fleet.to_json()),
            ("end_time", Json::Num(res.end_time)),
            ("timeline", tel.timeline),
            ("attribution", tel.attribution),
            (
                "perfetto",
                Json::Str(tel.perfetto.expect("perfetto requested")),
            ),
        ])
        .to_string()
    };
    let a = dump(&trace, &cfg);
    let b = dump(&trace, &cfg);
    assert_eq!(a, b, "same seed must reproduce byte-identical output");

    // And the schedule actually injected faults (mean 120 s over a 90 s
    // trace + drain across 8 instances fires with near-certainty).
    let res = simulate_fleet(&trace, &cfg);
    assert!(
        res.fleet.crashes + res.fleet.skipped_faults > 0,
        "stochastic schedule produced no fault events"
    );
    assert_eq!(res.fleet.accounting_errors, 0);

    // A different seed diverges (sanity: the harness is sensitive).
    let mut cfg2 = cfg.clone();
    cfg2.sim.seed = 12;
    let c = dump(&trace, &cfg2);
    assert_ne!(a, c, "seeds indistinguishable");
}

/// The fault injector never kills the last live instance of a pool: with a
/// 1-instance relaxed pool every relaxed crash is refused, and the run
/// behaves exactly like its zero-fault twin.
#[test]
fn last_live_instance_is_never_killed() {
    let trace = mixed_trace(60.0, 21);
    let mut cfg = fleet_cfg(ServingConfig::preset_7b());
    cfg.fault = "crash(at=10,pool=relaxed,inst=0,down=60)".parse().unwrap();

    let res = simulate_fleet(&trace, &cfg);
    assert_eq!(res.fleet.crashes, 0);
    assert_eq!(res.fleet.skipped_faults, 1);
    assert!((res.fleet.availability - 1.0).abs() < 1e-12);
    assert_eq!(res.fleet.accounting_errors, 0);

    let mut zero = cfg.clone();
    zero.fault = FaultSpec::none();
    let twin = simulate_fleet(&trace, &zero);
    assert_eq!(
        res.report.to_json().to_string(),
        twin.report.to_json().to_string(),
        "a fully-refused schedule must not perturb the run"
    );
}

/// Multi-replica routing + stealing: arrivals spread over the replicas,
/// offline backlog imbalances drain through work stealing, and nothing is
/// lost in transit.
#[test]
fn multi_replica_steals_and_conserves() {
    // Offline-heavy load so backlogs form and starved replicas steal.
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.4, 60.0, 31);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 5.0, 60.0, 32);
    let trace = online.merge(offline);
    let mut cfg = fleet_cfg(ServingConfig::preset_7b());
    cfg.sim.drain_s = 3000.0;
    cfg.fleet.replicas = 3;

    let mut fleet = Fleet::new(&trace, &cfg);
    let res = fleet.run(&trace);

    assert_eq!(res.fleet.accounting_errors, 0);
    assert_eq!(res.report.online_finished, res.report.online_total);
    assert!(
        res.report.offline_finished > 0,
        "no offline work completed"
    );
    // All replicas participated.
    for i in 0..3 {
        let cluster = &fleet.replica(i).cluster;
        assert!(
            cluster.requests.iter().any(|r| r.finished_at.is_some()),
            "replica {i} served nothing"
        );
    }
}

/// Power-of-two-choices routing is deterministic under a fixed seed and
/// still spreads load over the replicas.
#[test]
fn p2c_routing_is_seeded_and_spreads() {
    let trace = mixed_trace(90.0, 47);
    let mut cfg = fleet_cfg(ServingConfig::preset_7b());
    cfg.fleet.replicas = 2;
    cfg.fleet.route = "p2c".parse().unwrap();

    let run = |cfg: &FleetConfig| {
        let mut fleet = Fleet::new(&trace, cfg);
        let res = fleet.run(&trace);
        let served: Vec<usize> = (0..2)
            .map(|i| {
                fleet
                    .replica(i)
                    .cluster
                    .requests
                    .iter()
                    .filter(|r| r.finished_at.is_some())
                    .count()
            })
            .collect();
        (res.report.to_json().to_string(), served)
    };
    let (a, served_a) = run(&cfg);
    let (b, served_b) = run(&cfg);
    assert_eq!(a, b, "p2c must draw from the seeded route stream");
    assert_eq!(served_a, served_b);
    assert!(
        served_a.iter().all(|&n| n > 0),
        "p2c starved a replica: {served_a:?}"
    );
}
