//! Integration tests: the discrete-event simulator end-to-end, across
//! policies, with conservation and determinism checks.

use ooco::config::ServingConfig;
use ooco::coordinator::{Ablation, Policy};
use ooco::request::Class;
use ooco::sim::{simulate, SimConfig, SimResult};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;

fn mixed_trace(online_rate: f64, offline_qps: f64, duration: f64, seed: u64) -> Trace {
    let online = online_trace(DatasetProfile::azure_conv(), online_rate, duration, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), offline_qps, duration, seed + 1);
    online.merge(offline)
}

fn run(policy: Policy, online_rate: f64, offline_qps: f64, duration: f64) -> SimResult {
    let trace = mixed_trace(online_rate, offline_qps, duration, 42);
    let cfg = SimConfig::new(ServingConfig::preset_7b(), policy);
    simulate(&trace, &cfg)
}

#[test]
fn pure_online_light_load_meets_slo() {
    let res = run(Policy::Ooco, 0.5, 0.0, 900.0);
    let rep = &res.report;
    assert!(rep.online_total > 100, "online_total {}", rep.online_total);
    assert_eq!(rep.online_finished, rep.online_total, "all must finish");
    assert!(
        rep.online_violation_rate < 0.03,
        "violation {} ({})",
        rep.online_violation_rate,
        rep.summary_line()
    );
    // TTFT ~ queue + prefill: well under a second at this load.
    assert!(rep.ttft.p50 < 1.0, "ttft p50 {}", rep.ttft.p50);
    // TPOT bounded by the SLO-aware batching.
    assert!(rep.tpot.p99 <= 0.101, "tpot p99 {}", rep.tpot.p99);
}

#[test]
fn ooco_serves_offline_without_breaking_online() {
    let res = run(Policy::Ooco, 0.5, 1.0, 900.0);
    let rep = &res.report;
    assert!(
        rep.online_violation_rate < 0.03,
        "violations {} ({})",
        rep.online_violation_rate,
        rep.summary_line()
    );
    assert!(
        rep.offline_token_throughput > 50.0,
        "offline throughput {}",
        rep.offline_token_throughput
    );
    assert!(rep.offline_finished > 0);
}

#[test]
fn base_pd_collapses_under_offline_load() {
    // With offline requests treated as online and no protection, a heavy
    // offline stream (~10 qps saturates the strict pool's decode capacity)
    // must push violations past the 3% threshold while OOCO stays clean.
    let base = run(Policy::BasePd, 0.5, 10.0, 900.0);
    let ooco = run(Policy::Ooco, 0.5, 10.0, 900.0);
    assert!(
        base.report.online_violation_rate > 0.03,
        "base should collapse: {}",
        base.report.online_violation_rate
    );
    assert!(
        ooco.report.online_violation_rate < 0.03,
        "ooco should hold: {}",
        ooco.report.online_violation_rate
    );
}

#[test]
fn ooco_beats_online_priority_offline_throughput() {
    // At saturating offline load, OOCO's SLO-aware mix-in and migration
    // must deliver more offline tokens than the static-cap baseline.
    let op = run(Policy::OnlinePriority, 0.5, 20.0, 900.0);
    let ooco = run(Policy::Ooco, 0.5, 20.0, 900.0);
    assert!(
        ooco.report.offline_token_throughput
            > 1.1 * op.report.offline_token_throughput,
        "ooco {} vs op {}",
        ooco.report.offline_token_throughput,
        op.report.offline_token_throughput
    );
    // And both keep the online SLO at this online load.
    assert!(ooco.report.online_violation_rate < 0.03);
    assert!(op.report.online_violation_rate < 0.03);
}

#[test]
fn deterministic_across_runs() {
    let a = run(Policy::Ooco, 0.4, 0.8, 600.0);
    let b = run(Policy::Ooco, 0.4, 0.8, 600.0);
    assert_eq!(a.report.online_total, b.report.online_total);
    assert_eq!(a.report.online_violations, b.report.online_violations);
    assert_eq!(a.report.offline_finished, b.report.offline_finished);
    assert_eq!(a.strict_steps, b.strict_steps);
    assert_eq!(a.migrations, b.migrations);
    assert!((a.report.ttft.p99 - b.report.ttft.p99).abs() < 1e-12);
}

#[test]
fn ooco_uses_migration_and_mixin() {
    let res = run(Policy::Ooco, 0.4, 1.5, 900.0);
    assert!(res.migrations > 0, "no migrations happened");
    assert!(
        res.strict_offline_tokens > 0,
        "no offline tokens decoded on strict nodes"
    );
}

#[test]
fn baselines_never_migrate() {
    for policy in [Policy::BasePd, Policy::OnlinePriority] {
        let res = run(policy, 0.4, 1.0, 600.0);
        assert_eq!(res.migrations, 0, "{policy:?} migrated");
    }
}

#[test]
fn preemption_only_with_protection_policies() {
    let base = run(Policy::BasePd, 0.6, 1.0, 600.0);
    assert_eq!(base.preemptions, 0);
    // OOCO preempts offline prefill when online arrives mid-step.
    let ooco = run(Policy::Ooco, 0.6, 1.5, 900.0);
    assert!(ooco.preemptions > 0, "expected some preemptions");
}

#[test]
fn offline_only_trace_all_classes_finish_eventually() {
    let trace = offline_trace(DatasetProfile::ooc_offline(), 0.5, 600.0, 3);
    let mut cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.drain_s = 3000.0;
    let res = simulate(&trace, &cfg);
    let rep = &res.report;
    assert_eq!(rep.online_total, 0);
    assert!(
        rep.offline_finished as f64 >= 0.9 * rep.offline_total as f64,
        "finished {}/{}",
        rep.offline_finished,
        rep.offline_total
    );
}

#[test]
fn online_class_requests_keep_slo_fields() {
    let trace = mixed_trace(0.3, 0.5, 300.0, 9);
    let cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    let res = simulate(&trace, &cfg);
    // Spot check: finished online requests all have ttft + tpot recorded.
    assert!(res.report.ttft.count > 0);
    assert!(res.report.tpot.count > 0);
    assert!(res.report.ttft.min >= 0.0);
    assert!(res.report.tpot.min >= 0.0);
}

#[test]
fn ablations_change_behavior() {
    let trace = mixed_trace(0.5, 1.5, 900.0, 21);
    let serving = ServingConfig::preset_7b();
    let mut full = SimConfig::new(serving.clone(), Policy::Ooco);
    full.ablation = Ablation::full();
    let full_res = simulate(&trace, &full);

    let mut no_mig = SimConfig::new(serving.clone(), Policy::Ooco);
    no_mig.ablation = Ablation::without_migration();
    let no_mig_res = simulate(&trace, &no_mig);
    assert_eq!(no_mig_res.migrations, 0);
    // Without migration the strict pool decodes fewer offline tokens.
    assert!(
        no_mig_res.strict_offline_tokens < full_res.strict_offline_tokens,
        "full {} no-mig {}",
        full_res.strict_offline_tokens,
        no_mig_res.strict_offline_tokens
    );
}

#[test]
fn heavier_offline_load_more_offline_throughput_until_saturation() {
    let lo = run(Policy::Ooco, 0.4, 0.5, 900.0);
    let hi = run(Policy::Ooco, 0.4, 1.5, 900.0);
    assert!(
        hi.report.offline_token_throughput > lo.report.offline_token_throughput,
        "lo {} hi {}",
        lo.report.offline_token_throughput,
        hi.report.offline_token_throughput
    );
}

#[test]
fn utilization_sane() {
    let res = run(Policy::Ooco, 0.5, 1.0, 900.0);
    assert!(res.strict_utilization > 0.05 && res.strict_utilization <= 1.5);
    assert!(res.relaxed_utilization > 0.05 && res.relaxed_utilization <= 1.5);
    assert!(res.strict_steps > 100);
}

#[test]
fn class_counts_conserved() {
    let trace = mixed_trace(0.4, 0.8, 600.0, 17);
    let n_online = trace.count_class(Class::Online);
    let n_offline = trace.count_class(Class::Offline);
    let cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    let res = simulate(&trace, &cfg);
    assert_eq!(res.report.online_total, n_online);
    assert_eq!(res.report.offline_total, n_offline);
}

#[test]
fn multi_instance_cluster_scales_capacity() {
    // 2 relaxed + 2 strict must sustain roughly double the online load of
    // 1+1 (router balances across the pools).
    let duration = 600.0;
    let trace = mixed_trace(1.2, 4.0, duration, 33);
    let mut small = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    small.seed = 33;
    let small_res = simulate(&trace, &small);

    let mut big_cfg = ServingConfig::preset_7b();
    big_cfg.cluster.relaxed_instances = 2;
    big_cfg.cluster.strict_instances = 2;
    let mut big = SimConfig::new(big_cfg, Policy::Ooco);
    big.seed = 33;
    let big_res = simulate(&trace, &big);

    // Same workload, more instances: violations cannot be worse and
    // per-instance utilization drops.
    assert!(
        big_res.report.online_violation_rate
            <= small_res.report.online_violation_rate + 1e-9
    );
    assert!(big_res.strict_utilization < small_res.strict_utilization);
    assert!(big_res.report.offline_token_throughput
        >= small_res.report.offline_token_throughput * 0.95);
}

#[test]
fn multi_instance_conservation() {
    let trace = mixed_trace(0.8, 2.0, 400.0, 55);
    let mut cfg_s = ServingConfig::preset_7b();
    cfg_s.cluster.relaxed_instances = 3;
    cfg_s.cluster.strict_instances = 2;
    let mut cfg = SimConfig::new(cfg_s, Policy::Ooco);
    cfg.drain_s = 2000.0;
    let res = simulate(&trace, &cfg);
    assert_eq!(
        res.report.online_total,
        trace.count_class(Class::Online)
    );
    assert_eq!(res.report.online_finished, res.report.online_total);
}

#[test]
fn shed_mode_caps_tpot_at_overload() {
    use ooco::coordinator::OverloadMode;
    // Online load far beyond capacity: best-effort lets TPOT blow up;
    // shed mode keeps the survivors' TPOT p50 under the bound at the cost
    // of sacrificed requests (higher violation count).
    let trace = mixed_trace(8.0, 0.0, 400.0, 77);
    let mk = |mode| {
        let mut cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.overload_mode = mode;
        simulate(&trace, &cfg)
    };
    let best = mk(OverloadMode::BestEffort);
    let shed = mk(OverloadMode::Shed);
    let slo = ServingConfig::preset_7b().slo;
    // Both overloaded...
    assert!(best.report.online_violation_rate > slo.violation_threshold);
    assert!(shed.report.online_violation_rate > slo.violation_threshold);
    // ...but shed keeps surviving decode steps within the bound.
    assert!(
        shed.report.tpot.p50 <= slo.tpot * 1.05,
        "shed tpot p50 {} > bound",
        shed.report.tpot.p50
    );
    assert!(
        shed.report.tpot.p50 <= best.report.tpot.p50,
        "shed {} vs best-effort {}",
        shed.report.tpot.p50,
        best.report.tpot.p50
    );
}

#[test]
fn shed_mode_noop_at_normal_load() {
    use ooco::coordinator::OverloadMode;
    let trace = mixed_trace(0.4, 1.0, 400.0, 88);
    let mk = |mode| {
        let mut cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.overload_mode = mode;
        simulate(&trace, &cfg)
    };
    let best = mk(OverloadMode::BestEffort);
    let shed = mk(OverloadMode::Shed);
    // Under the SLO nothing is ever shed: identical outcomes.
    assert_eq!(
        best.report.online_finished,
        shed.report.online_finished
    );
    assert_eq!(
        best.report.online_violations,
        shed.report.online_violations
    );
}
