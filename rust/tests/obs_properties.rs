//! Performance-observatory property tests (DESIGN.md §3.11):
//!
//! 1. **Pure observers**: arming the self-profiler changes nothing
//!    observable — the composed `--json-out` object (minus the `profile`
//!    key itself) is byte-identical between a profiled and an unprofiled
//!    same-seed run, for both the single-cluster and fleet paths.
//! 2. **Coverage**: on a non-trivial run the per-subsystem breakdown
//!    covers ≥90% of the measured span, self-times never exceed the
//!    span (exclusive accounting), and the event tally sum equals the
//!    loop's event count.
//! 3. **Fault tallies**: a faulted fleet run counts its crash-notice /
//!    crash / recover events.
//! 4. **OpenMetrics well-formedness**: the `--metrics-out` exposition
//!    has unique family names, `# HELP`/`# TYPE` preceding every
//!    family's samples, legal metric-name charset, and a terminating
//!    `# EOF`.
//! 5. **Bench suite**: `ooco bench`'s `run_suite` emits the
//!    schema-stable artifact with all four scenarios profiled.

use std::collections::BTreeMap;

use ooco::config::ServingConfig;
use ooco::coordinator::Policy;
use ooco::fleet::{self, simulate_fleet_observed, FleetConfig};
use ooco::obs;
use ooco::sim::{self, simulate_observed, SimConfig};
use ooco::telemetry::TelemetryOpts;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;

fn mixed_trace(duration: f64, seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.6, duration, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 2.0, duration, seed + 1);
    online.merge(offline)
}

fn sim_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.seed = seed;
    cfg.drain_s = 120.0;
    cfg
}

// ------------------------------------------------------- 1. pure observers

#[test]
fn profiling_is_a_pure_observer_single_cluster() {
    let trace = mixed_trace(120.0, 42);
    let cfg = sim_cfg(42);

    let plain = simulate_observed(&trace, &cfg, None, false);
    let profiled = simulate_observed(&trace, &cfg, None, true);
    assert!(plain.profile.is_none());
    let prof = profiled.profile.as_ref().expect("profile requested");
    assert!(prof.total_s > 0.0);

    let a = sim::result_json(&cfg, &plain);
    let mut b = sim::result_json(&cfg, &profiled);
    assert!(b.remove("profile").is_some(), "profiled run carries the key");
    assert_eq!(
        a.to_pretty(),
        b.to_pretty(),
        "profiling must not perturb any deterministic output"
    );
}

#[test]
fn profiling_is_a_pure_observer_with_telemetry() {
    // The telemetry tap is itself probed (Subsystem::Telemetry), so run
    // the identity check with the flight recorder attached too: timeline
    // and attribution must not move either.
    let trace = mixed_trace(90.0, 7);
    let cfg = sim_cfg(7);
    let opts = TelemetryOpts::new(cfg.serving.slo);

    let plain = simulate_observed(&trace, &cfg, Some(opts), false);
    let profiled = simulate_observed(&trace, &cfg, Some(opts), true);
    let a = sim::result_json(&cfg, &plain);
    let mut b = sim::result_json(&cfg, &profiled);
    b.remove("profile");
    assert_eq!(a.to_pretty(), b.to_pretty());
}

#[test]
fn profiling_is_a_pure_observer_fleet() {
    let trace = mixed_trace(90.0, 11);
    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 2;
    serving.cluster.strict_instances = 2;
    let mut simc = SimConfig::new(serving, Policy::Ooco);
    simc.seed = 11;
    simc.drain_s = 120.0;
    let mut cfg = FleetConfig::new(simc);
    cfg.fleet.replicas = 2;
    cfg.fault = "crash(at=20,pool=relaxed,inst=1,down=30,notice=10)"
        .parse()
        .unwrap();

    let plain = simulate_fleet_observed(&trace, &cfg, None, false);
    let profiled = simulate_fleet_observed(&trace, &cfg, None, true);
    let a = fleet::result_json(&cfg, &plain);
    let mut b = fleet::result_json(&cfg, &profiled);
    assert!(b.remove("profile").is_some());
    assert_eq!(a.to_pretty(), b.to_pretty());
}

// ------------------------------------------------- 2. coverage + tallies

#[test]
fn profile_breakdown_covers_the_span() {
    let trace = mixed_trace(300.0, 42);
    let cfg = sim_cfg(42);
    let res = simulate_observed(&trace, &cfg, None, true);
    let prof = res.profile.expect("profile requested");

    // Exclusive accounting: buckets can never sum past the span (small
    // tolerance for clock granularity).
    assert!(
        prof.covered_s <= prof.total_s * 1.02 + 1e-6,
        "covered {} > total {}",
        prof.covered_s,
        prof.total_s
    );
    // The acceptance bar: the breakdown explains ≥90% of loop time.
    assert!(
        prof.coverage >= 0.9,
        "coverage {:.3} below the 0.9 bar ({})",
        prof.coverage,
        prof.summary_line()
    );
    // One tally per popped loop event.
    assert_eq!(prof.event_total(), res.events, "event tallies must sum");
    for name in ["setup", "heap_pop", "heap_push", "scheduler", "metrics"] {
        assert!(
            prof.subsystems.iter().any(|s| s.name == name && s.calls > 0),
            "subsystem {name} never fired"
        );
    }
}

#[test]
fn fleet_profile_counts_fault_events() {
    let trace = mixed_trace(90.0, 13);
    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 2;
    serving.cluster.strict_instances = 2;
    let mut simc = SimConfig::new(serving, Policy::Ooco);
    simc.seed = 13;
    simc.drain_s = 120.0;
    let mut cfg = FleetConfig::new(simc);
    cfg.fleet.replicas = 2;
    cfg.fault = "crash(at=20,pool=relaxed,inst=1,down=30,notice=10)"
        .parse()
        .unwrap();

    let res = simulate_fleet_observed(&trace, &cfg, None, true);
    let prof = res.profile.expect("profile requested");
    assert_eq!(prof.event_total(), res.events);
    let count = |name: &str| {
        prof.events
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, n)| *n)
            .unwrap_or(0)
    };
    assert!(count("crash_notice") >= 1, "{:?}", prof.events);
    assert!(count("crash") >= 1);
    assert!(count("recover") >= 1);
    assert!(count("arrival") > 0);
    assert!(
        prof.subsystems.iter().any(|s| s.name == "fleet" && s.calls > 0),
        "fleet routing/steal probes never fired"
    );
}

// ------------------------------------------------ 4. OpenMetrics export

/// Minimal validator for the subset of the OpenMetrics text format the
/// exporter emits: `# HELP <name> ...` then `# TYPE <name> gauge` then
/// that family's samples, families unique, `# EOF` last.
fn assert_well_formed_openmetrics(text: &str) {
    assert!(text.ends_with("# EOF\n"), "missing terminating # EOF");
    let mut declared: BTreeMap<String, bool> = BTreeMap::new(); // name -> typed
    let mut pending_help: Option<String> = None;
    for line in text.lines() {
        if line == "# EOF" {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap().to_string();
            assert!(
                !declared.contains_key(&name),
                "family {name} declared twice"
            );
            declared.insert(name.clone(), false);
            pending_help = Some(name);
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap().to_string();
            assert_eq!(it.next(), Some("gauge"), "only gauges are emitted");
            assert_eq!(
                pending_help.as_deref(),
                Some(name.as_str()),
                "TYPE must directly follow its HELP"
            );
            declared.insert(name, true);
            pending_help = None;
        } else {
            // Sample line: <name>[{labels}] <value> [<ts>]
            let name_end = line
                .find(|c: char| c == '{' || c == ' ')
                .unwrap_or(line.len());
            let name = &line[..name_end];
            assert!(
                !name.is_empty()
                    && name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_')
                    && !name.starts_with(|c: char| c.is_ascii_digit()),
                "illegal metric name in line: {line}"
            );
            assert_eq!(
                declared.get(name),
                Some(&true),
                "sample before HELP/TYPE: {line}"
            );
            let after = &line[name_end..];
            let values = after
                .rsplit_once('}')
                .map(|(_, v)| v)
                .unwrap_or(after)
                .trim();
            for tok in values.split_whitespace() {
                tok.parse::<f64>()
                    .unwrap_or_else(|_| panic!("bad number in: {line}"));
            }
        }
    }
    assert!(!declared.is_empty(), "no metric families emitted");
}

#[test]
fn openmetrics_exposition_is_well_formed() {
    let trace = mixed_trace(120.0, 42);
    let cfg = sim_cfg(42);
    let mut opts = TelemetryOpts::new(cfg.serving.slo);
    opts.watch = Some(ooco::watch::WatchParams::new(cfg.serving.slo));
    let res = simulate_observed(&trace, &cfg, Some(opts), true);
    let mut out = sim::result_json(&cfg, &res);
    out.set("meta", obs::meta_json(cfg.seed, "test-config", 0.5));
    let text = obs::openmetrics::render(&out);
    assert_well_formed_openmetrics(&text);
    // Spot checks: headline report gauges, run metadata, timeline points.
    assert!(text.contains("ooco_report_"), "report section missing");
    assert!(
        text.contains("ooco_run_info{key=\"meta_version\""),
        "meta version label missing"
    );
    assert!(text.contains("ooco_timeline_"), "timeline section missing");
    assert!(text.contains("ooco_profile_coverage "), "profile missing");
    // Incident-engine families (§3.12): present and still well-formed.
    assert!(
        text.contains("ooco_incidents_active "),
        "incident active gauge missing"
    );
    assert!(
        text.contains("ooco_burn_rate{class=\"online-ttft\",window=\"fast\"}"),
        "burn-rate family missing"
    );
}

// ------------------------------------------------------- 5. bench suite

#[test]
fn bench_suite_emits_schema_stable_artifact() {
    // Tiny scale: the suite shape matters here, not the numbers.
    let (json, summaries) = obs::bench::run_suite(0.02, 42);
    assert_eq!(summaries.len(), 4);
    assert_eq!(
        json.get("schema").as_str(),
        Some(obs::bench::BENCH_SCHEMA)
    );
    assert!(json.get("headline_req_per_s").as_f64().unwrap() > 0.0);
    assert!(json.get("total").get("events").as_f64().unwrap() > 0.0);
    assert_eq!(
        json.get("meta").get("config_hash").as_str().unwrap().len(),
        16
    );
    let cases = json.get("cases").as_arr().expect("cases array");
    assert_eq!(cases.len(), 4);
    for case in cases {
        assert!(case.get("requests").as_f64().unwrap() > 0.0);
        assert!(
            case.get("profile").get("coverage").as_f64().is_some(),
            "every case is self-profiled"
        );
    }
    // The artifact renders cleanly as OpenMetrics too (CI publishes it).
    assert_well_formed_openmetrics(&obs::openmetrics::render(&json));
}
