//! Differential tests for the unified scheduling API: the same
//! `SchedulerCore` driven by two independently implemented executors — the
//! discrete-event `VirtualExecutor` (binary-heap queue, virtual clock) and
//! the engine-shaped `StubWallClockExecutor` (linear-scan agenda, stub wall
//! clock) — must emit byte-identical `Action` streams, *including* the
//! chunk-level transfer progress/completion ordering produced by the KV
//! transport subsystem under link contention. This is the structural proof
//! behind the paper's "only the clock is virtual" claim.
//!
//! Plus property tests over `select_decode_batch_capped`: selections never
//! exceed the configured cap nor the KV tokens actually resident on the
//! instance (its KvManager-bounded candidate pool).

use std::collections::HashMap;

use ooco::config::{ChunkMode, LinkSharing, PoolPolicy, ServingConfig};
use ooco::coordinator::{Ablation, OverloadMode};
use ooco::prop_assert;
use ooco::scheduler::{
    select_decode_batch_capped, Action, Candidate, CoreConfig, Executor,
    Policy, RolePhase, SchedulerCore, StubWallClockExecutor, VirtualExecutor,
};
use ooco::testutil::forall;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{
    offline_trace, offline_trace_with_prefix, online_trace, two_phase_trace,
    PrefixProfile,
};
use ooco::trace::Trace;

fn mixed_trace(duration: f64, seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.6, duration, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 1.5, duration, seed + 1);
    online.merge(offline)
}

/// The acceptance-criterion test: identical action streams under both
/// substrates, for every policy.
#[test]
fn action_streams_identical_across_executors_all_policies() {
    let trace = mixed_trace(90.0, 42);
    let horizon = trace.duration() + 300.0;
    for policy in Policy::all() {
        let mut virt = VirtualExecutor::new(&trace, horizon);
        virt.log = Some(Vec::new());
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), policy);
        cfg.seed = 11;
        let mut core_v = SchedulerCore::new(trace.requests.clone(), cfg.clone());
        virt.run(&mut core_v).unwrap();

        let mut stub = StubWallClockExecutor::new(&trace, horizon);
        stub.log = Some(Vec::new());
        let mut core_s = SchedulerCore::new(trace.requests.clone(), cfg);
        stub.run(&mut core_s).unwrap();

        let (v, s) = (virt.log.unwrap(), stub.log.unwrap());
        assert!(!v.is_empty(), "{policy:?}: empty action stream");
        assert_eq!(
            v.len(),
            s.len(),
            "{policy:?}: stream lengths differ ({} vs {})",
            v.len(),
            s.len()
        );
        for (i, (a, b)) in v.iter().zip(&s).enumerate() {
            assert_eq!(a, b, "{policy:?}: streams diverge at action {i}");
        }
        // And the decisions left both clusters in identical shape.
        assert_eq!(core_v.cluster.preemptions, core_s.cluster.preemptions);
        assert_eq!(core_v.cluster.evictions, core_s.cluster.evictions);
        assert_eq!(core_v.cluster.migrations, core_s.cluster.migrations);
    }
}

/// The stream is rich under OOCO: it must exercise step starts, transfers,
/// completions, and offline admissions (the four coordinator scheduling
/// points leave visible traces).
#[test]
fn ooco_stream_covers_action_vocabulary() {
    let trace = mixed_trace(120.0, 7);
    let horizon = trace.duration() + 300.0;
    let mut virt = VirtualExecutor::new(&trace, horizon);
    virt.log = Some(Vec::new());
    let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.seed = 11;
    let mut core = SchedulerCore::new(trace.requests.clone(), cfg);
    virt.run(&mut core).unwrap();
    let stream = virt.log.unwrap();
    let has = |pred: fn(&Action) -> bool| stream.iter().any(pred);
    assert!(has(|a| matches!(a, Action::StartStep { .. })), "no steps");
    assert!(
        has(|a| matches!(a, Action::TransferStart { .. })),
        "no transfer jobs"
    );
    assert!(
        has(|a| matches!(a, Action::TransferChunk { .. })),
        "no transfer chunks"
    );
    assert!(
        has(|a| matches!(a, Action::TransferDone { .. })),
        "no transfer completions"
    );
    assert!(has(|a| matches!(a, Action::Complete { .. })), "no completions");
    assert!(has(|a| matches!(a, Action::Admit { .. })), "no admissions");
    assert_transfer_protocol(&stream);
}

/// Every transfer job in a stream must obey the chunk protocol: start
/// first, chunks in index order (each chunk order is only issued once its
/// predecessor completed), completion exactly after the last chunk, and
/// nothing after a cancel.
fn assert_transfer_protocol(stream: &[Action]) {
    // job -> (total chunks, next expected chunk index, done)
    let mut jobs: HashMap<u64, (usize, usize, bool)> = HashMap::new();
    for a in stream {
        match a {
            Action::TransferStart { job, chunks, .. } => {
                assert!(
                    jobs.insert(*job, (*chunks, 0, false)).is_none(),
                    "job {job} started twice"
                );
            }
            Action::TransferChunk { job, chunk, .. } => {
                let e = jobs.get_mut(job).expect("chunk before TransferStart");
                assert!(!e.2, "chunk after TransferDone on job {job}");
                assert_eq!(
                    *chunk, e.1,
                    "job {job}: chunk orders out of sequence"
                );
                e.1 += 1;
                assert!(e.1 <= e.0, "job {job}: more chunks than planned");
            }
            Action::TransferDone { job, .. } => {
                let e = jobs.get_mut(job).expect("done before TransferStart");
                assert_eq!(
                    e.1, e.0,
                    "job {job}: TransferDone before all chunks served"
                );
                assert!(!e.2, "job {job} completed twice");
                e.2 = true;
            }
            Action::TransferCancel { job, .. } => {
                assert!(
                    jobs.remove(job).is_some(),
                    "cancel of unknown job {job}"
                );
            }
            _ => {}
        }
    }
}

/// The acceptance-criterion test for the transport subsystem: with a
/// constrained, fair-shared interconnect (so concurrent migrations contend
/// and chunk orders interleave across jobs), both executors still emit
/// identical action streams for every policy — and the streams obey the
/// chunk protocol.
#[test]
fn chunked_transfers_differential_under_contention() {
    let trace = mixed_trace(120.0, 13);
    let horizon = trace.duration() + 600.0;
    for policy in Policy::all() {
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), policy);
        cfg.seed = 23;
        // ~50x less interconnect bandwidth than the 910c default, shared
        // fairly: transfers queue, stall, and interleave.
        cfg.serving.transport.pool.bandwidth = 0.5e9;
        cfg.serving.transport.pool.sharing = LinkSharing::FairShare;
        cfg.serving.transport.host.bandwidth = 1e9;

        let mut virt = VirtualExecutor::new(&trace, horizon);
        virt.log = Some(Vec::new());
        let mut core_v = SchedulerCore::new(trace.requests.clone(), cfg.clone());
        virt.run(&mut core_v).unwrap();

        let mut stub = StubWallClockExecutor::new(&trace, horizon);
        stub.log = Some(Vec::new());
        let mut core_s = SchedulerCore::new(trace.requests.clone(), cfg);
        stub.run(&mut core_s).unwrap();

        let (v, s) = (virt.log.unwrap(), stub.log.unwrap());
        assert_eq!(
            v.len(),
            s.len(),
            "{policy:?}: stream lengths differ ({} vs {})",
            v.len(),
            s.len()
        );
        for (i, (a, b)) in v.iter().zip(&s).enumerate() {
            assert_eq!(a, b, "{policy:?}: streams diverge at action {i}");
        }
        assert_transfer_protocol(&v);
        assert!(
            v.iter().any(|a| matches!(a, Action::TransferChunk { .. })),
            "{policy:?}: no chunked transfers in stream"
        );
        // The constrained link must actually have contended.
        assert!(
            core_v.transport.links()[0].stall_s > 0.0,
            "{policy:?}: no transfer stall despite 50x bandwidth cut"
        );
        assert_eq!(core_v.cluster.rescues, core_s.cluster.rescues);
        assert_eq!(core_v.cluster.offloads, core_s.cluster.offloads);
    }
}

/// Elastic-pools acceptance criterion: with the pool manager re-planning
/// every 20 s over a regime-change trace, both executors still emit
/// identical action streams for every policy — and those streams carry the
/// full repartition timeline (`RepartitionPlan`, every `RoleChange` phase,
/// warm steps included), proving the plan/transition machinery is part of
/// the substrate-independent decision core.
#[test]
fn elastic_repartition_streams_identical_across_executors() {
    // Heavy-then-light online phases force the planner to grow and then
    // shrink the strict pool; the squeezed memory makes the per-instance
    // KV capacity bind at test-scale load.
    let trace = two_phase_trace(
        DatasetProfile::azure_conv(),
        5.0,
        0.5,
        120.0,
        DatasetProfile::ooc_offline(),
        1.0,
        31,
    );
    let horizon = trace.duration() + 300.0;

    for policy in Policy::all() {
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), policy);
        cfg.seed = 17;
        cfg.serving.hardware.mem_capacity = 20e9;
        cfg.serving.cluster.relaxed_instances = 3;
        cfg.serving.cluster.strict_instances = 1;
        cfg.serving.pool = PoolPolicy::Periodic {
            epoch_s: 20.0,
            headroom: 0.15,
        };

        let mut virt = VirtualExecutor::new(&trace, horizon);
        virt.log = Some(Vec::new());
        let mut core_v = SchedulerCore::new(trace.requests.clone(), cfg.clone());
        virt.run(&mut core_v).unwrap();

        let mut stub = StubWallClockExecutor::new(&trace, horizon);
        stub.log = Some(Vec::new());
        let mut core_s = SchedulerCore::new(trace.requests.clone(), cfg);
        stub.run(&mut core_s).unwrap();

        let (v, s) = (virt.log.unwrap(), stub.log.unwrap());
        assert_eq!(
            v.len(),
            s.len(),
            "{policy:?}: stream lengths differ ({} vs {})",
            v.len(),
            s.len()
        );
        for (i, (a, b)) in v.iter().zip(&s).enumerate() {
            assert_eq!(a, b, "{policy:?}: streams diverge at action {i}");
        }
        // The plan timeline is present and the transition machinery ran.
        assert!(
            v.iter()
                .any(|a| matches!(a, Action::RepartitionPlan { .. })),
            "{policy:?}: no repartition plans in stream"
        );
        for phase in [RolePhase::Drain, RolePhase::Flip, RolePhase::Warm] {
            assert!(
                v.iter().any(|a| matches!(
                    a,
                    Action::RoleChange { phase: p, .. } if *p == phase
                )),
                "{policy:?}: no RoleChange {phase:?} in stream"
            );
        }
        assert_eq!(
            core_v.pool_report().flips,
            core_s.pool_report().flips,
            "{policy:?}: flip counts diverge"
        );
        assert!(core_v.pool_report().flips >= 1, "{policy:?}: no flips");
        assert_eq!(core_v.cluster.total_instances(), 4);
    }
}

/// Prefix-cache acceptance criterion (DESIGN.md §3.7): on a shared-prefix
/// trace with squeezed memory — so lookups hit, the LRU churns, and
/// copy-on-write partial reuse occurs — both executors emit identical
/// action streams for every policy, and the streams carry the
/// hit/miss/evict vocabulary (`PrefixResolve` with and without cached
/// tokens, `PrefixEvict`).
#[test]
fn prefix_cache_streams_identical_across_executors() {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.4, 90.0, 21);
    let offline = offline_trace_with_prefix(
        DatasetProfile::ooc_offline(),
        2.0,
        90.0,
        PrefixProfile::FewShot { groups: 12, prefix_len: 1000 },
        22,
    );
    let trace = online.merge(offline);
    let horizon = trace.duration() + 300.0;
    for policy in Policy::all() {
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), policy);
        cfg.seed = 29;
        // Squeeze KV so admissions + decode growth churn the cache
        // (weights ~15.2 GB, so ~31k KV tokens per instance — a dozen
        // 1000-token template chains plus a handful of residents saturate
        // it).
        cfg.serving.hardware.mem_capacity = 17e9;

        let mut virt = VirtualExecutor::new(&trace, horizon);
        virt.log = Some(Vec::new());
        let mut core_v = SchedulerCore::new(trace.requests.clone(), cfg.clone());
        virt.run(&mut core_v).unwrap();

        let mut stub = StubWallClockExecutor::new(&trace, horizon);
        stub.log = Some(Vec::new());
        let mut core_s = SchedulerCore::new(trace.requests.clone(), cfg);
        stub.run(&mut core_s).unwrap();

        let (v, s) = (virt.log.unwrap(), stub.log.unwrap());
        assert_eq!(
            v.len(),
            s.len(),
            "{policy:?}: stream lengths differ ({} vs {})",
            v.len(),
            s.len()
        );
        for (i, (a, b)) in v.iter().zip(&s).enumerate() {
            assert_eq!(a, b, "{policy:?}: streams diverge at action {i}");
        }
        assert!(
            v.iter().any(|a| matches!(
                a,
                Action::PrefixResolve { cached_tokens, .. } if *cached_tokens > 0
            )),
            "{policy:?}: no cache hits on a shared-prefix trace"
        );
        // LRU churn is mechanically certain under OOCO (offline decode
        // residents grow on the relaxed pool until allocation dips into
        // the reclaimable cache); the baselines keep less relaxed-side
        // state, so only the identity of their streams is asserted.
        if policy == Policy::Ooco {
            assert!(
                v.iter().any(
                    |a| matches!(a, Action::PrefixEvict { blocks, .. } if *blocks > 0)
                ),
                "squeezed memory must churn the cache LRU"
            );
        }
        // The resolutions the cores recorded agree, and the cached-token
        // counts ride the prefill StartSteps.
        let rep_v = core_v.prefix_report();
        let rep_s = core_s.prefix_report();
        assert_eq!(rep_v.lookups, rep_s.lookups, "{policy:?}");
        assert_eq!(rep_v.hits, rep_s.hits, "{policy:?}");
        assert_eq!(
            rep_v.prefill_tokens_saved, rep_s.prefill_tokens_saved,
            "{policy:?}"
        );
        assert!(rep_v.hits > 0, "{policy:?}: zero hits");
        let stepped: usize = v
            .iter()
            .filter_map(|a| match a {
                Action::StartStep { cached_tokens, .. } => Some(*cached_tokens),
                _ => None,
            })
            .sum();
        assert_eq!(
            stepped as u64, rep_v.prefill_tokens_saved,
            "{policy:?}: StartStep cached-token counts must equal the report"
        );
    }
}

/// With the cache disabled, shared-prefix traces behave like cold
/// workloads: no resolutions, no savings — the off switch is the bench's
/// baseline.
#[test]
fn prefix_cache_disabled_is_cold() {
    let trace = offline_trace_with_prefix(
        DatasetProfile::ooc_offline(),
        1.5,
        60.0,
        PrefixProfile::SharedSystem { prefix_len: 1000 },
        23,
    );
    let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.serving.prefix.enabled = false;
    let mut virt = VirtualExecutor::new(&trace, trace.duration() + 300.0);
    virt.log = Some(Vec::new());
    let mut core = SchedulerCore::new(trace.requests.clone(), cfg);
    virt.run(&mut core).unwrap();
    let log = virt.log.unwrap();
    assert!(!log
        .iter()
        .any(|a| matches!(a, Action::PrefixResolve { .. })));
    let rep = core.prefix_report();
    assert!(!rep.enabled);
    assert_eq!(rep.lookups, 0);
    assert_eq!(rep.prefill_tokens_saved, 0);
}

/// Chunked-prefill acceptance criterion (DESIGN.md §3.8): with chunking
/// on (`auto` and a fixed budget) and off, on a long-prompt + offline
/// co-locate trace, both executors emit identical action streams for
/// every policy — and the chunked streams actually carry composed
/// iterations with prefill segments.
#[test]
fn chunked_prefill_differential_on_and_off_all_policies() {
    use ooco::trace::PromptProfile;
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.5, 90.0, 51);
    let offline = offline_trace(
        PromptProfile::DEFAULT_LONG.apply(&DatasetProfile::ooc_offline()),
        0.8,
        90.0,
        52,
    );
    let trace = online.merge(offline);
    let horizon = trace.duration() + 300.0;
    for mode in [ChunkMode::Auto, ChunkMode::Fixed(2048), ChunkMode::Off] {
        for policy in Policy::all() {
            let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), policy);
            cfg.seed = 37;
            cfg.serving.chunk_tokens = mode;

            let mut virt = VirtualExecutor::new(&trace, horizon);
            virt.log = Some(Vec::new());
            let mut core_v =
                SchedulerCore::new(trace.requests.clone(), cfg.clone());
            virt.run(&mut core_v).unwrap();

            let mut stub = StubWallClockExecutor::new(&trace, horizon);
            stub.log = Some(Vec::new());
            let mut core_s = SchedulerCore::new(trace.requests.clone(), cfg);
            stub.run(&mut core_s).unwrap();

            let (v, s) = (virt.log.unwrap(), stub.log.unwrap());
            assert_eq!(
                v.len(),
                s.len(),
                "{policy:?}/{mode:?}: stream lengths differ ({} vs {})",
                v.len(),
                s.len()
            );
            for (i, (a, b)) in v.iter().zip(&s).enumerate() {
                assert_eq!(
                    a, b,
                    "{policy:?}/{mode:?}: streams diverge at action {i}"
                );
            }
            let composed = v.iter().any(|a| {
                matches!(
                    a,
                    Action::StartStep { prefill, .. } if !prefill.is_empty()
                )
            });
            if mode.is_enabled() {
                assert!(
                    composed,
                    "{policy:?}/{mode:?}: no composed prefill iterations"
                );
                assert_eq!(
                    core_v.chunk_report().preempted_work_discarded,
                    0,
                    "{policy:?}/{mode:?}: chunked mode must never discard"
                );
            } else {
                assert!(
                    !composed,
                    "{policy:?}: exclusive mode must not compose"
                );
            }
            assert_eq!(
                core_v.cluster.chunk_accounting_errors, 0,
                "{policy:?}/{mode:?}: chunk conservation violated"
            );
            assert_eq!(
                core_v.cluster.preemptions,
                core_s.cluster.preemptions,
                "{policy:?}/{mode:?}"
            );
        }
    }
}

#[test]
fn base_pd_and_ooco_streams_differ() {
    // Sanity: the differential harness is sensitive — different policies
    // must produce different streams on the same trace.
    let trace = mixed_trace(90.0, 42);
    let horizon = trace.duration() + 300.0;
    let mut streams = Vec::new();
    for policy in [Policy::BasePd, Policy::Ooco] {
        let mut virt = VirtualExecutor::new(&trace, horizon);
        virt.log = Some(Vec::new());
        let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), policy);
        cfg.seed = 11;
        let mut core = SchedulerCore::new(trace.requests.clone(), cfg);
        virt.run(&mut core).unwrap();
        streams.push(virt.log.unwrap());
    }
    assert_ne!(streams[0], streams[1], "policies indistinguishable");
}

#[test]
fn shed_overload_mode_still_differential() {
    // Overload shedding is a §3.4.4 decision; it too must be
    // substrate-independent.
    let online = online_trace(DatasetProfile::azure_conv(), 6.0, 40.0, 5);
    let horizon = online.duration() + 120.0;
    let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.overload_mode = OverloadMode::Shed;
    cfg.ablation = Ablation::full();

    let mut virt = VirtualExecutor::new(&online, horizon);
    virt.log = Some(Vec::new());
    let mut core_v = SchedulerCore::new(online.requests.clone(), cfg.clone());
    virt.run(&mut core_v).unwrap();

    let mut stub = StubWallClockExecutor::new(&online, horizon);
    stub.log = Some(Vec::new());
    let mut core_s = SchedulerCore::new(online.requests.clone(), cfg);
    stub.run(&mut core_s).unwrap();

    assert_eq!(virt.log, stub.log);
}

// ------------------------------------------------------ capped selection

#[test]
fn capped_selection_never_exceeds_cap_or_resident_kv() {
    forall(80, |r| {
        let n_on = r.below(12);
        let n_off = r.below(60);
        let online: Vec<Candidate> = (0..n_on)
            .map(|i| (i as u64, r.below(3000) + 1))
            .collect();
        let offline: Vec<Candidate> = (0..n_off)
            .map(|i| (1000 + i as u64, r.below(3000) + 1))
            .collect();
        // Candidates are KV residents of one instance, so their total
        // tokens bound what any legal selection may reference.
        let resident_kv: usize =
            online.iter().chain(&offline).map(|c| c.1).sum();
        let cap = r.below(80);
        let sel = select_decode_batch_capped(&online, &offline, cap);

        // 1. Batch size never exceeds the cap (beyond the always-included
        //    online set, which the §3.4.4 contract admits unconditionally).
        prop_assert!(
            sel.stats.size <= cap.max(online.len()),
            "size {} > cap {} (online {})",
            sel.stats.size,
            cap,
            online.len()
        );
        prop_assert!(
            online.len() + sel.offline.len() == sel.stats.size,
            "stats size mismatch"
        );

        // 2. Selection KV never exceeds the instance's resident KV.
        prop_assert!(
            sel.stats.total_kv_tokens <= resident_kv,
            "selection kv {} > resident {}",
            sel.stats.total_kv_tokens,
            resident_kv
        );

        // 3. Chosen offline ids come from the candidate set, once each, in
        //    arrival order (the baseline's greedy contract).
        let mut last_idx = None;
        for id in &sel.offline {
            let idx = offline
                .iter()
                .position(|c| c.0 == *id)
                .expect("foreign id");
            if let Some(prev) = last_idx {
                prop_assert!(idx > prev, "not arrival-ordered");
            }
            last_idx = Some(idx);
        }

        // 4. Exact KV accounting: stats equal online + chosen aggregates.
        let chosen_kv: usize = sel
            .offline
            .iter()
            .map(|id| offline.iter().find(|c| c.0 == *id).unwrap().1)
            .sum();
        let online_kv: usize = online.iter().map(|c| c.1).sum();
        prop_assert!(
            sel.stats.total_kv_tokens == online_kv + chosen_kv,
            "kv accounting off"
        );
        Ok(())
    });
}
