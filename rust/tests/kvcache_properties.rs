//! Property tests for the refcounted, prefix-sharing KV allocator
//! (DESIGN.md §3.7): block conservation across alloc/share/cow/free
//! cycles, no double-free, LRU eviction never reclaiming a pinned or
//! referenced block, and `free_tokens` honesty under sharing.
//!
//! The external model mirrors how the scheduler uses the allocator: plain
//! admissions, chain registrations (`mark_cached` over a resident's full
//! blocks), shared admissions validated the way the prefix index validates
//! (`is_cached` per block), growth, and release — with
//! `KvManager::check_invariants` auditing the internal state after every
//! operation.

use ooco::kvcache::KvManager;
use ooco::prop_assert;
use ooco::testutil::forall;

const BT: usize = 16;

struct LiveReq {
    id: u64,
    tokens: usize,
    /// The cache blocks this admission referenced (must stay its block
    /// prefix, verbatim, for its whole life).
    shared: Vec<u32>,
}

#[test]
fn refcounted_allocator_invariants_under_churn() {
    forall(40, |r| {
        let total_blocks = 20 + r.below(60); // 20..=79 blocks
        let mut kv = KvManager::new(total_blocks * BT, BT);
        let mut live: Vec<LiveReq> = Vec::new();
        let mut chains: Vec<Vec<u32>> = Vec::new();
        let mut next_id = 0u64;

        for _ in 0..400 {
            match r.below(6) {
                0 | 1 => {
                    // Plain (cold) admission.
                    let toks = r.below(6 * BT) + 1;
                    if kv.admit(next_id, toks).is_ok() {
                        live.push(LiveReq {
                            id: next_id,
                            tokens: toks,
                            shared: Vec::new(),
                        });
                    }
                    next_id += 1;
                }
                2 => {
                    // Register a resident's full blocks as a cached chain
                    // (the shape of a prefix-index insertion).
                    if !live.is_empty() {
                        let lr = &live[r.below(live.len())];
                        let blocks = kv.blocks_of(lr.id).unwrap().to_vec();
                        let full = lr.tokens / BT;
                        if full > 0 {
                            for &b in &blocks[..full] {
                                kv.mark_cached(b);
                            }
                            chains.push(blocks[..full].to_vec());
                        }
                    }
                }
                3 => {
                    // Shared admission off a chain, validated per block the
                    // way the index validates (stale entries skipped), with
                    // occasional copy-on-write partial reuse.
                    if !chains.is_empty() {
                        let chain = chains[r.below(chains.len())].clone();
                        let valid: Vec<u32> = chain
                            .iter()
                            .copied()
                            .take_while(|&b| kv.is_cached(b))
                            .collect();
                        let shared: Vec<u32> =
                            valid.iter().copied().take(1 + r.below(4)).collect();
                        let partial = if valid.len() > shared.len()
                            && r.below(2) == 0
                        {
                            Some((valid[shared.len()], 1 + r.below(BT - 1)))
                        } else {
                            None
                        };
                        let toks = shared.len() * BT + r.below(3 * BT) + 1;
                        if kv.can_admit_shared(toks, &shared) {
                            kv.admit_shared(next_id, toks, &shared, partial)
                                .unwrap();
                            live.push(LiveReq {
                                id: next_id,
                                tokens: toks,
                                shared,
                            });
                        } else {
                            prop_assert!(
                                kv.admit_shared(next_id, toks, &shared, partial)
                                    .is_err(),
                                "can_admit_shared said no but admit succeeded"
                            );
                        }
                        next_id += 1;
                    }
                }
                4 => {
                    // Decode growth.
                    if !live.is_empty() {
                        let i = r.below(live.len());
                        let extra = r.below(2 * BT) + 1;
                        if kv.grow(live[i].id, extra).is_ok() {
                            live[i].tokens += extra;
                        }
                    }
                }
                5 => {
                    // Release (finish/evict/migrate-out).
                    if !live.is_empty() {
                        let i = r.below(live.len());
                        let lr = live.swap_remove(i);
                        let toks = kv.release(lr.id).unwrap();
                        prop_assert!(
                            toks == lr.tokens,
                            "release token drift: {toks} vs {}",
                            lr.tokens
                        );
                    }
                }
                _ => unreachable!(),
            }

            // Full internal audit after every operation: refcounts equal
            // owner counts, every block exactly one of free / pinned /
            // reclaimable, free list duplicate-free.
            kv.check_invariants()?;

            for lr in &live {
                let blocks = kv.blocks_of(lr.id).expect("live resident");
                prop_assert!(
                    kv.tokens_of(lr.id) == lr.tokens,
                    "tokens drift for {}",
                    lr.id
                );
                prop_assert!(
                    blocks.len() == kv.blocks_needed(lr.tokens),
                    "block-count drift for {}",
                    lr.id
                );
                // Reclamation/CoW must never touch a live request's shared
                // prefix references.
                prop_assert!(
                    blocks[..lr.shared.len()] == lr.shared[..],
                    "shared prefix of {} was stolen",
                    lr.id
                );
            }

            prop_assert!(
                kv.free_tokens()
                    == (kv.free_blocks() + kv.reclaimable_blocks()) * BT,
                "free_tokens must count free + reclaimable blocks"
            );

            // Eviction never reclaims a pinned or referenced block: every
            // logged reclaim is absent from all live shared prefixes.
            for b in kv.take_reclaimed() {
                for lr in &live {
                    prop_assert!(
                        !lr.shared.contains(&b),
                        "reclaimed block {b} was pinned by {}",
                        lr.id
                    );
                }
            }
        }

        // free_tokens honesty, end to end: exactly what it promises must
        // be admittable in one go (reclaiming cached blocks on demand).
        let promised = kv.free_tokens();
        if promised > 0 {
            kv.admit(next_id, promised).map_err(|e| {
                format!("free_tokens promised {promised} tokens: {e}")
            })?;
            live.push(LiveReq {
                id: next_id,
                tokens: promised,
                shared: Vec::new(),
            });
        }

        // Teardown: releasing every request and unmarking every chain must
        // restore the whole pool — no leaks, no double-frees.
        for lr in live.drain(..) {
            kv.release(lr.id).unwrap();
        }
        for chain in chains {
            for b in chain {
                let _ = kv.unmark_cached(b);
            }
        }
        kv.check_invariants()?;
        prop_assert!(
            kv.free_blocks() == kv.total_blocks(),
            "pool not restored: {} of {} blocks free",
            kv.free_blocks(),
            kv.total_blocks()
        );
        Ok(())
    });
}

/// Directed share/cow/free cycle: the exact lifecycle the scheduler drives
/// — prefill + register, sharers arrive (full refs + CoW partial), owners
/// leave (chain demotes to reclaimable), memory pressure reclaims LRU —
/// conserving blocks at every stage.
#[test]
fn share_cow_free_cycle_conserves_blocks() {
    let mut kv = KvManager::new(12 * BT, BT);
    // Prefill a 40-token request; register its chain (2 full + partial).
    kv.admit(1, 40).unwrap();
    let blocks = kv.blocks_of(1).unwrap().to_vec();
    for &b in &blocks {
        kv.mark_cached(b);
    }
    assert_eq!(kv.used_blocks(), 3);
    assert_eq!(kv.reclaimable_blocks(), 0); // pinned by request 1

    // A sharer references both full blocks and CoW-reuses the partial.
    kv.admit_shared(2, 50, &blocks[..2], Some((blocks[2], 8))).unwrap();
    assert_eq!(kv.cow_copies, 1);
    // 3 (req 1) + 2 private tail blocks for req 2's tokens 33..=50.
    assert_eq!(kv.used_blocks(), 5);
    kv.check_invariants().unwrap();

    // Owners leave: the chain becomes reclaimable capacity.
    kv.release(1).unwrap();
    kv.release(2).unwrap();
    assert_eq!(kv.reclaimable_blocks(), 3);
    assert_eq!(kv.pinned_blocks(), 0);
    assert_eq!(kv.free_tokens(), 12 * BT);
    kv.check_invariants().unwrap();

    // Memory pressure: a full-pool admission reclaims the LRU chain.
    kv.admit(3, 12 * BT).unwrap();
    assert_eq!(kv.free_blocks(), 0);
    assert_eq!(kv.reclaimable_blocks(), 0);
    let reclaimed = kv.take_reclaimed();
    assert_eq!(reclaimed.len(), 3, "the whole chain was reclaimed");
    kv.release(3).unwrap();
    kv.check_invariants().unwrap();
    assert_eq!(kv.free_blocks(), kv.total_blocks());
}
