//! Queue-swap differential suite (DESIGN.md §3.13): the calendar/bucket
//! event queue and the explicit binary heap implement the same
//! `(time, insertion-order)` contract, so swapping one for the other must
//! leave every deterministic output byte-identical — the machine-readable
//! `--json-out` document (report, transport, pool, prefix, chunk,
//! telemetry timeline, attribution, Perfetto buffer), for every policy,
//! and for a faulted multi-replica fleet run. This is the acceptance
//! criterion that lets the calendar queue be the default: if it ever
//! reorders a tie or drops an event, these string comparisons catch the
//! first diverging byte.

use ooco::config::ServingConfig;
use ooco::coordinator::Policy;
use ooco::fleet::{simulate_fleet_queued, FleetConfig};
use ooco::sim::{result_json, simulate_queued, QueueKind, SimConfig};
use ooco::telemetry::TelemetryOpts;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;
use ooco::util::json::Json;

fn mixed_trace(duration: f64, seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.6, duration, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 1.5, duration, seed + 1);
    online.merge(offline)
}

/// The tentpole acceptance test: for every policy, the full
/// machine-readable result — telemetry armed, Perfetto on — is
/// byte-identical across the two queue implementations.
#[test]
fn json_out_identical_across_queues_all_policies() {
    let trace = mixed_trace(90.0, 42);
    for policy in Policy::all() {
        let mut cfg = SimConfig::new(ServingConfig::preset_7b(), policy);
        cfg.seed = 11;
        let run = |kind: QueueKind| {
            let mut opts = TelemetryOpts::new(cfg.serving.slo);
            opts.perfetto = true;
            let res = simulate_queued(&trace, &cfg, Some(opts), false, kind);
            let doc = result_json(&cfg, &res).to_string();
            let perfetto = res
                .telemetry
                .as_ref()
                .and_then(|t| t.perfetto.clone())
                .expect("perfetto requested");
            (doc, perfetto)
        };
        let (cal_doc, cal_perfetto) = run(QueueKind::Calendar);
        let (heap_doc, heap_perfetto) = run(QueueKind::BinaryHeap);
        assert!(
            cal_doc.contains("\"timeline\""),
            "{policy:?}: telemetry missing from result document"
        );
        assert_eq!(
            cal_doc, heap_doc,
            "{policy:?}: queue swap changed the --json-out document"
        );
        assert_eq!(
            cal_perfetto, heap_perfetto,
            "{policy:?}: queue swap changed the Perfetto buffer"
        );
    }
}

/// The fleet half: a faulted 2-replica fleet — crash, failover, recovery,
/// work stealing all in play — still produces byte-identical report,
/// fleet counters, gauge timeline, and attribution across the queue swap.
#[test]
fn faulted_fleet_identical_across_queues() {
    let trace = mixed_trace(60.0, 7);
    let mut sim = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    sim.seed = 11;
    sim.drain_s = 3000.0;
    let mut cfg = FleetConfig::new(sim);
    cfg.fleet.replicas = 2;
    cfg.fault = "crash(at=20,pool=relaxed,inst=0,down=30)".parse().unwrap();

    let run = |kind: QueueKind| {
        let opts = TelemetryOpts::new(cfg.sim.serving.slo);
        let res = simulate_fleet_queued(&trace, &cfg, Some(opts), false, kind);
        let tel = res.telemetry.expect("telemetry requested");
        (
            Json::obj(vec![
                ("report", res.report.to_json()),
                ("fleet", res.fleet.to_json()),
                ("end_time", Json::Num(res.end_time)),
                ("timeline", tel.timeline),
                ("attribution", tel.attribution),
            ])
            .to_string(),
            res.fleet.crashes,
        )
    };
    let (cal, cal_crashes) = run(QueueKind::Calendar);
    let (heap, heap_crashes) = run(QueueKind::BinaryHeap);
    assert!(cal_crashes >= 1, "fault schedule never fired");
    assert_eq!(cal_crashes, heap_crashes);
    assert_eq!(
        cal, heap,
        "queue swap changed the faulted fleet's machine-readable output"
    );
}

/// Sanity for the harness itself: the two queue kinds are actually
/// different code paths — a run on each must *touch* the calendar's
/// overflow/rebuild machinery. We can't observe internals from here, so
/// instead pin the sensitivity of the comparison: different seeds
/// diverge, proving byte-equality above is not vacuous.
#[test]
fn differential_harness_is_sensitive() {
    let trace = mixed_trace(60.0, 3);
    let mut cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.seed = 11;
    let a = result_json(
        &cfg,
        &simulate_queued(&trace, &cfg, None, false, QueueKind::Calendar),
    )
    .to_string();
    cfg.seed = 12;
    let b = result_json(
        &cfg,
        &simulate_queued(&trace, &cfg, None, false, QueueKind::Calendar),
    )
    .to_string();
    assert_ne!(a, b, "seeds indistinguishable — comparisons are vacuous");
}
