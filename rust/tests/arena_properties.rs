//! Generational-arena property tests (DESIGN.md §3.13): the arena's
//! generation counters are the structural guard that makes slot reuse
//! safe — the same role the sequence-id staleness checks play for
//! step-end and transfer events in the event loops. Three properties:
//!
//! 1. **Model equivalence under random churn**: against a reference map,
//!    every live handle reads its value and every dead handle reads
//!    `None`, across arbitrary insert/remove interleavings.
//! 2. **No aliasing after index reuse**: a handle invalidated by removal
//!    never resolves again, no matter how many later entries recycle its
//!    slot (the flip/crash index-reuse hazard).
//! 3. **Conservation through a faulted fleet run**: the end-to-end check
//!    that the recycled-state machinery never loses a request — a
//!    crash/recover fleet on the calendar queue finishes with zero
//!    accounting errors and exact per-request token conservation.

use std::collections::HashMap;

use ooco::config::ServingConfig;
use ooco::coordinator::Policy;
use ooco::fleet::{simulate_fleet_queued, FleetConfig};
use ooco::prop_assert;
use ooco::request::{Arena, GenId};
use ooco::sim::{QueueKind, SimConfig};
use ooco::testutil::forall;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};

/// Property 1: the arena agrees with a reference `HashMap` model under
/// random insert/remove interleavings, and stale handles stay dead.
#[test]
fn arena_matches_model_under_random_churn() {
    forall(60, |r| {
        let mut arena: Arena<u64> = Arena::new();
        let mut model: HashMap<GenId, u64> = HashMap::new();
        let mut dead: Vec<GenId> = Vec::new();
        let mut next_value = 0u64;
        let ops = 200 + r.below(200);
        for _ in 0..ops {
            // Bias toward inserts so the arena grows, but churn enough
            // that slots recycle (removal picks an arbitrary live id).
            if model.is_empty() || r.chance(0.6) {
                let id = arena.insert(next_value);
                prop_assert!(
                    model.insert(id, next_value).is_none(),
                    "arena issued a duplicate live handle {id:?}"
                );
                next_value += 1;
            } else {
                let pick = r.below(model.len());
                let id = *model.keys().nth(pick).unwrap();
                let expect = model.remove(&id).unwrap();
                prop_assert!(
                    arena.remove(id) == Some(expect),
                    "remove({id:?}) lost value {expect}"
                );
                dead.push(id);
            }
            prop_assert!(
                arena.len() == model.len(),
                "len {} != model {}",
                arena.len(),
                model.len()
            );
        }
        for (id, v) in &model {
            prop_assert!(
                arena.get(*id) == Some(v),
                "live handle {id:?} lost its value"
            );
        }
        for id in &dead {
            prop_assert!(
                arena.get(*id).is_none() && !arena.contains(*id),
                "dead handle {id:?} resolved after removal"
            );
        }
        // The iterator sees exactly the live set.
        let mut live: Vec<u64> = arena.iter().map(|(_, v)| *v).collect();
        let mut expect: Vec<u64> = model.values().copied().collect();
        live.sort_unstable();
        expect.sort_unstable();
        prop_assert!(live == expect, "iterator disagrees with model");
        Ok(())
    });
}

/// Property 2: once removed, a handle never aliases — even when its slot
/// is recycled through many generations by later entries.
#[test]
fn stale_handles_never_alias_across_generations() {
    forall(40, |r| {
        let mut arena: Arena<u64> = Arena::new();
        // A small arena so every removal's slot is certain to recycle.
        let seed: Vec<GenId> = (0..4).map(|i| arena.insert(i)).collect();
        let mut graveyard: Vec<GenId> = Vec::new();
        let mut live = seed;
        let mut next_value = 4u64;
        for _ in 0..100 {
            // Kill one live entry, then immediately refill: LIFO free
            // list guarantees the dead slot is reused under a bumped
            // generation.
            let victim = live.swap_remove(r.below(live.len()));
            arena.remove(victim).unwrap();
            graveyard.push(victim);
            let fresh = arena.insert(next_value);
            next_value += 1;
            prop_assert!(
                fresh.index() == victim.index(),
                "LIFO free list skipped the freed slot"
            );
            prop_assert!(
                fresh.generation() != victim.generation(),
                "slot reused without a generation bump"
            );
            live.push(fresh);
            // Every handle ever killed stays dead.
            for id in &graveyard {
                prop_assert!(
                    arena.get(*id).is_none(),
                    "stale handle {id:?} aliased a recycled slot"
                );
                prop_assert!(
                    arena.remove(*id).is_none(),
                    "stale handle {id:?} removed a recycled entry"
                );
            }
        }
        prop_assert!(
            arena.capacity_slots() == 4,
            "churn grew the arena: {} slots",
            arena.capacity_slots()
        );
        Ok(())
    });
}

/// Property 3: the end-to-end conservation check. A crash/recover fleet
/// run on the calendar queue — the configuration where recycled slots,
/// recycled action vecs, and event staleness guards are all in play —
/// loses no request and conserves every finished request's tokens.
#[test]
fn faulted_fleet_run_conserves_requests() {
    let online = online_trace(DatasetProfile::azure_conv(), 0.6, 60.0, 7);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 1.5, 60.0, 8);
    let trace = online.merge(offline);

    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 2;
    serving.cluster.strict_instances = 2;
    let mut sim = SimConfig::new(serving, Policy::Ooco);
    sim.seed = 11;
    sim.drain_s = 3000.0;
    let mut cfg = FleetConfig::new(sim);
    cfg.fleet.replicas = 2;
    cfg.fault =
        "crash(at=20,pool=relaxed,inst=0,down=30); \
         crash(at=25,pool=strict,inst=1,down=30)"
            .parse()
            .unwrap();

    let res = simulate_fleet_queued(
        &trace,
        &cfg,
        None,
        false,
        QueueKind::Calendar,
    );
    assert!(res.fleet.crashes >= 1, "fault schedule never fired");
    assert_eq!(
        res.fleet.accounting_errors, 0,
        "a request fell out of every scheduling structure"
    );
    assert_eq!(
        res.report.online_finished, res.report.online_total,
        "online requests must all finish despite the crashes"
    );
    assert!(
        res.report.offline_finished as f64
            >= 0.9 * res.report.offline_total as f64,
        "offline finished {}/{}",
        res.report.offline_finished,
        res.report.offline_total
    );
}
