//! Flight-recorder property tests (DESIGN.md §3.10):
//!
//! 1. **Span well-formedness**: every opened step span is closed by a
//!    successor, a preemption/crash path, or the end-of-run force close
//!    (at most one per instance track); track-local timestamps never
//!    regress; no action names an instance outside the registered
//!    topology.
//! 2. **Chunk-span conservation**: for every completed chunked-prefill
//!    request, the announced composed segments of its final attempt sum
//!    exactly to the measured `prefill_target - prefill_cached` —
//!    across prefix hits, preemption, eviction, and recompute churn.
//! 3. **Attribution exactness**: each violated online request's TTFT
//!    components (queueing, transfer stall, chunk interference,
//!    compute) sum to the measured TTFT within 1e-6.
//! 4. **Perfetto structure**: the exported trace parses, and a faulted
//!    fleet run carries cross-instance flow arrows (`s`/`f` events).
//! 5. **Determinism**: same seed, same telemetry bytes.

use ooco::config::{ChunkMode, ServingConfig};
use ooco::coordinator::Policy;
use ooco::fleet::{simulate_fleet_traced, FleetConfig};
use ooco::sim::{simulate_traced, SimConfig};
use ooco::telemetry::{SpanAudit, TelemetryOpts, TelemetryOut};
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace, PromptProfile};
use ooco::trace::Trace;
use ooco::util::json::Json;

fn mixed_trace(duration: f64, seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.6, duration, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 1.5, duration, seed + 1);
    online.merge(offline)
}

/// Long offline prompts so composed iterations carry real chunk trains.
fn chunky_trace(duration: f64, seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.5, duration, seed);
    let offline = offline_trace(
        PromptProfile::DEFAULT_LONG.apply(&DatasetProfile::ooc_offline()),
        0.8,
        duration,
        seed + 1,
    );
    online.merge(offline)
}

/// The structural invariants every run must satisfy, regardless of
/// iteration mode, policy, or faults.
fn assert_spans_well_formed(audit: &SpanAudit, max_instances: u64) {
    assert_eq!(
        audit.opened_spans,
        audit.closed_spans + audit.force_closed_spans,
        "span conservation: opened != closed + force-closed"
    );
    assert!(
        audit.force_closed_spans <= max_instances,
        "more force-closed spans ({}) than instance tracks ({})",
        audit.force_closed_spans,
        max_instances
    );
    assert!(audit.opened_spans > 0, "run recorded no steps");
    assert_eq!(audit.monotone_violations, 0, "track timestamps regressed");
    assert_eq!(
        audit.dangling_instance_refs, 0,
        "action named an unregistered instance"
    );
    assert_eq!(
        audit.chunk_mismatches, 0,
        "chunk spans did not sum to the measured prefill target"
    );
    assert!(
        audit.max_attr_residual <= 1e-6,
        "attribution residual {} exceeds 1e-6",
        audit.max_attr_residual
    );
}

/// Walk the attribution rows: every row with a measured TTFT must carry
/// components that sum back to it within 1e-6. Returns the number of
/// rows checked.
fn assert_rows_exact(tel: &TelemetryOut) -> usize {
    let rows = tel
        .attribution
        .get("requests")
        .as_arr()
        .expect("attribution.requests is an array");
    let mut checked = 0;
    for row in rows {
        let comp = row.get("ttft_components");
        let (Some(ttft), Some(_)) =
            (row.get("ttft").as_f64(), comp.as_obj())
        else {
            continue;
        };
        let sum = comp.get("sum").as_f64().expect("component sum");
        assert!(
            (sum - ttft).abs() <= 1e-6,
            "request {:?}: components sum {} != ttft {}",
            row.get("id").as_f64(),
            sum,
            ttft
        );
        for k in
            ["queueing", "transfer_stall", "chunk_interference", "compute"]
        {
            let v = comp.get(k).as_f64().expect("component value");
            assert!(v >= -1e-6, "negative {k} component: {v}");
        }
        checked += 1;
    }
    checked
}

fn assert_timeline_sane(tel: &TelemetryOut) {
    let samples = tel.timeline.as_arr().expect("timeline is an array");
    assert!(!samples.is_empty(), "gauge sampler produced nothing");
    let mut last_t = f64::NEG_INFINITY;
    for s in samples {
        let t = s.get("t").as_f64().expect("sample time");
        assert!(t >= last_t, "timeline samples out of order");
        last_t = t;
        let frac = s.get("kv_used_frac").as_f64().expect("kv gauge");
        assert!(
            (0.0..=1.0 + 1e-9).contains(&frac),
            "kv_used_frac out of range: {frac}"
        );
        let att = s.get("slo_attainment").as_f64().expect("slo gauge");
        assert!((0.0..=1.0 + 1e-9).contains(&att));
    }
}

/// Chunked-mode run with a deliberately unattainable SLO so every online
/// request lands in the attribution report: spans close, chunk spans
/// conserve, and TTFT decompositions reproduce the measured latencies.
#[test]
fn chunked_run_spans_close_and_attribution_is_exact() {
    let trace = chunky_trace(90.0, 61);
    let mut serving = ServingConfig::preset_7b();
    serving.chunk_tokens = ChunkMode::Auto;
    let mut cfg = SimConfig::new(serving, Policy::Ooco);
    cfg.seed = 23;

    // The recorder judges against an unattainable SLO — every finished
    // online request lands in the attribution report — while the
    // scheduler keeps its real one (the serving SLO drives admission
    // and chunk budgets; zeroing it would degenerate the run).
    let mut slo = cfg.serving.slo;
    slo.ttft = 0.0;
    slo.tpot = 0.0;
    let opts = TelemetryOpts::new(slo);
    let res = simulate_traced(&trace, &cfg, Some(opts));
    let tel = res.telemetry.expect("telemetry requested");

    let instances = (cfg.serving.cluster.relaxed_instances
        + cfg.serving.cluster.strict_instances) as u64;
    assert_spans_well_formed(&tel.audit, instances);
    assert!(
        tel.audit.chunk_audited > 0,
        "chunked mode produced no audited chunk trains"
    );
    let checked = assert_rows_exact(&tel);
    assert!(checked > 20, "too few attribution rows checked ({checked})");
    assert_eq!(
        tel.audit.attribution_rows,
        tel.attribution
            .get("requests")
            .as_arr()
            .expect("rows")
            .len() as u64
    );
    assert_timeline_sane(&tel);
    assert!(tel.perfetto.is_none(), "perfetto not requested");
}

/// Exclusive-mode (chunking off) runs keep the same structural
/// invariants; exclusive prefills are exempt from the chunk audit, so
/// nothing is audited — and nothing mismatches.
#[test]
fn exclusive_run_spans_close() {
    let trace = mixed_trace(90.0, 67);
    let mut serving = ServingConfig::preset_7b();
    serving.chunk_tokens = ChunkMode::Off;
    let mut cfg = SimConfig::new(serving, Policy::Ooco);
    cfg.seed = 29;

    // Recorder-side SLO only (see the chunked twin above).
    let mut slo = cfg.serving.slo;
    slo.ttft = 0.0;
    let opts = TelemetryOpts::new(slo);
    let res = simulate_traced(&trace, &cfg, Some(opts));
    let tel = res.telemetry.expect("telemetry requested");
    let instances = (cfg.serving.cluster.relaxed_instances
        + cfg.serving.cluster.strict_instances) as u64;
    assert_spans_well_formed(&tel.audit, instances);
    assert_eq!(
        tel.audit.chunk_audited, 0,
        "exclusive mode must not enter the chunk audit"
    );
    assert_rows_exact(&tel);
    assert_timeline_sane(&tel);
}

/// A faulted fleet run: crashes force-close step spans mid-run, evicted
/// KV re-routes over the transport, and the Perfetto export carries the
/// resulting cross-instance flow arrows.
#[test]
fn faulted_fleet_trace_has_flows_and_clean_spans() {
    let trace = mixed_trace(60.0, 7);
    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 2;
    serving.cluster.strict_instances = 2;
    let mut sim = SimConfig::new(serving, Policy::Ooco);
    sim.seed = 11;
    sim.drain_s = 3000.0;
    let mut cfg = FleetConfig::new(sim);
    cfg.fault =
        "crash(at=20,pool=relaxed,inst=0,down=30); \
         crash(at=25,pool=strict,inst=1,down=30)"
            .parse()
            .unwrap();

    let mut opts = TelemetryOpts::new(cfg.sim.serving.slo);
    opts.perfetto = true;
    let res = simulate_fleet_traced(&trace, &cfg, Some(opts));
    let tel = res.telemetry.expect("telemetry requested");

    assert_spans_well_formed(&tel.audit, 4);
    assert_rows_exact(&tel);
    assert_timeline_sane(&tel);

    let raw = tel.perfetto.as_ref().expect("perfetto requested");
    let parsed = Json::parse(raw).expect("trace must parse");
    let events = parsed
        .get("traceEvents")
        .as_arr()
        .expect("traceEvents array");
    assert!(!events.is_empty());
    let count = |ph: &str| {
        events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some(ph))
            .count()
    };
    assert!(count("X") > 0, "no duration slices");
    assert!(count("C") > 0, "no counter samples");
    assert!(count("i") > 0, "no instant markers");
    assert!(
        count("s") > 0 && count("f") > 0,
        "faulted run produced no KV flow arrows (s={}, f={})",
        count("s"),
        count("f")
    );
    // Crash windows render as explicit fault slices.
    assert!(
        events.iter().any(|e| e.get("cat").as_str() == Some("fault")),
        "no fault events in a crashed run"
    );
}

/// Same seed, same telemetry bytes — the single-cluster twin of the
/// fleet determinism test (which covers stochastic faults).
#[test]
fn sim_telemetry_is_deterministic() {
    let trace = chunky_trace(60.0, 83);
    let mut serving = ServingConfig::preset_7b();
    serving.chunk_tokens = ChunkMode::Auto;
    let mut cfg = SimConfig::new(serving, Policy::Ooco);
    cfg.seed = 41;

    let dump = || {
        let mut slo = cfg.serving.slo;
        slo.ttft = 0.0;
        let mut opts = TelemetryOpts::new(slo);
        opts.perfetto = true;
        let tel = simulate_traced(&trace, &cfg, Some(opts))
            .telemetry
            .expect("telemetry requested");
        Json::obj(vec![
            ("timeline", tel.timeline.clone()),
            ("attribution", tel.attribution.clone()),
            ("perfetto", Json::Str(tel.perfetto.clone().expect("on"))),
        ])
        .to_string()
    };
    let a = dump();
    let b = dump();
    assert_eq!(a, b, "same seed must reproduce byte-identical telemetry");
}
