//! Incident-engine property tests (DESIGN.md §3.12):
//!
//! 1. **Determinism**: a faulted fleet run produces a byte-identical
//!    incident ledger under the same seed, and the crash window is
//!    covered by a fault incident on the crashed replica.
//! 2. **Hysteresis**: a trace oscillating around the burn threshold
//!    opens exactly one incident — the half-threshold band plus the
//!    clear-tick cooldown prevent flapping.
//! 3. **Conservation**: one sustained violation burst maps to exactly
//!    one covering burn incident (opened inside the burst, closed after
//!    the fast window drains).
//! 4. **Pure observer**: arming the watchdog changes nothing but the
//!    `incidents` key — the rest of the composed `--json-out` object is
//!    byte-identical to a watchdog-less run.

use ooco::config::ServingConfig;
use ooco::coordinator::Policy;
use ooco::fleet::{simulate_fleet_traced, FleetConfig};
use ooco::sim::{simulate_traced, SimConfig};
use ooco::telemetry::TelemetryOpts;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;
use ooco::util::json::Json;
use ooco::watch::{WatchParams, Watchdog};

fn mixed_trace(duration: f64, seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.6, duration, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 1.5, duration, seed + 1);
    online.merge(offline)
}

/// Faulted 2-replica fleet: same seed, byte-identical ledger; the crash
/// window is covered by a fault incident pinned to the crashed replica.
#[test]
fn faulted_fleet_ledger_is_deterministic_and_covers_the_crash() {
    let trace = mixed_trace(120.0, 7);
    let mut serving = ServingConfig::preset_7b();
    serving.cluster.relaxed_instances = 1;
    serving.cluster.strict_instances = 1;
    let mut sim = SimConfig::new(serving, Policy::Ooco);
    sim.seed = 11;
    let mut cfg = FleetConfig::new(sim);
    cfg.fleet = "2".parse().unwrap();
    cfg.fault = "crash(at=40,replica=0,pool=relaxed,inst=0,down=40)"
        .parse()
        .unwrap();

    let dump = || {
        let mut opts = TelemetryOpts::new(cfg.sim.serving.slo);
        opts.watch = Some(WatchParams::new(cfg.sim.serving.slo));
        let tel = simulate_fleet_traced(&trace, &cfg, Some(opts))
            .telemetry
            .expect("telemetry requested");
        tel.incidents.expect("watchdog armed").to_pretty()
    };
    let a = dump();
    let b = dump();
    assert_eq!(a, b, "same seed must reproduce a byte-identical ledger");

    let ledger = Json::parse(&a).expect("ledger parses");
    assert!(
        ledger.get("total").as_f64().unwrap_or(0.0) >= 1.0,
        "crashed fleet recorded no incidents"
    );
    let rows = ledger.get("incidents").as_arr().expect("incident rows");
    let fault = rows
        .iter()
        .find(|r| r.get("kind").as_str() == Some("fault"))
        .expect("crash produced no fault incident");
    assert_eq!(fault.get("replica").as_f64(), Some(0.0));
    assert_eq!(fault.get("cause").as_str(), Some("fault"));
    let opened = fault.get("opened_at").as_f64().expect("opened_at");
    let closed =
        fault.get("closed_at").as_f64().unwrap_or(f64::INFINITY);
    // The crash window is [40, 80]; the incident must overlap it.
    assert!(
        opened < 80.0 && closed > 40.0,
        "fault incident [{opened}, {closed}] misses the crash window \
         [40, 80]"
    );
}

/// Drive a watchdog directly with completions at 1/s. `violated(t)`
/// decides the TTFT outcome; TPOT always passes. Ticks ride along at
/// the same cadence.
fn drive(
    until_s: usize,
    violated: impl Fn(usize) -> bool,
) -> Vec<ooco::watch::Incident> {
    let serving = ServingConfig::preset_7b();
    let params = WatchParams::new(serving.slo);
    let mut w = Watchdog::new(params, &serving);
    for t in 0..until_s {
        let now = t as f64;
        w.on_online_complete(now, !violated(t), true);
        w.on_tick(now);
    }
    w.finish(until_s as f64).incidents
}

/// A trace oscillating around the open threshold must not flap: the
/// half-threshold hysteresis band keeps the one incident open while the
/// burn hovers, and the cooldown closes it only after sustained calm.
#[test]
fn boundary_oscillation_opens_exactly_one_incident() {
    // Violate every other second for 300 s (fast burn oscillates around
    // 0.5/budget ≈ 16x — well above threshold, but the *instantaneous*
    // reading swings tick to tick), then go fully clean for 120 s.
    let incidents =
        drive(420, |t| t < 300 && t % 2 == 0);
    let burns: Vec<_> = incidents
        .iter()
        .filter(|i| i.kind == ooco::watch::IncidentKind::SloBurn)
        .collect();
    assert_eq!(
        burns.len(),
        1,
        "oscillating trace flapped into {} incidents",
        burns.len()
    );
    let inc = burns[0];
    assert!(
        inc.closed_at.is_some(),
        "incident must close once the trace goes clean"
    );
    assert!(
        inc.closed_at.unwrap() > 300.0,
        "incident closed at {:?} while the oscillation was still hot",
        inc.closed_at
    );
}

/// One sustained violation burst maps to exactly one covering incident:
/// opened inside the burst, closed only after the fast window drains.
#[test]
fn sustained_burst_is_covered_by_exactly_one_incident() {
    let incidents = drive(400, |t| (100..200).contains(&t));
    let burns: Vec<_> = incidents
        .iter()
        .filter(|i| i.kind == ooco::watch::IncidentKind::SloBurn)
        .collect();
    assert_eq!(
        burns.len(),
        1,
        "one burst must map to one incident, got {}",
        burns.len()
    );
    let inc = burns[0];
    assert!(
        (100.0..200.0).contains(&inc.opened_at),
        "opened at {} outside the burst [100, 200)",
        inc.opened_at
    );
    let closed = inc.closed_at.expect("burst incident must close");
    assert!(
        closed >= 200.0,
        "closed at {closed} before the burst ended"
    );
    assert_eq!(inc.class, Some("online"));
    assert_eq!(inc.metric, Some("ttft"));
}

/// Arming the watchdog must not perturb the run: the composed
/// `--json-out` object minus the `incidents` key is byte-identical to a
/// watchdog-less run, and the watchdog-less object has no such key.
#[test]
fn armed_watchdog_is_a_pure_observer() {
    let trace = mixed_trace(90.0, 67);
    let mut cfg =
        SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.seed = 29;

    let run = |watch: bool| {
        let mut opts = TelemetryOpts::new(cfg.serving.slo);
        if watch {
            opts.watch = Some(WatchParams::new(cfg.serving.slo));
        }
        let res = simulate_traced(&trace, &cfg, Some(opts));
        ooco::sim::result_json(&cfg, &res).to_pretty()
    };
    let off = run(false);
    let on = run(true);

    let mut on_json = Json::parse(&on).expect("watch-on result parses");
    if let Json::Obj(m) = &mut on_json {
        assert!(
            m.remove("incidents").is_some(),
            "armed run must emit an incidents key"
        );
    } else {
        panic!("result is not an object");
    }
    assert_eq!(
        on_json.to_pretty(),
        off,
        "watchdog perturbed the run beyond the incidents key"
    );

    let off_json = Json::parse(&off).expect("watch-off result parses");
    assert!(
        off_json.get("incidents").as_obj().is_none(),
        "watchdog-less run must not emit an incidents key"
    );
}
