//! Property and acceptance tests for the chunked-prefill continuous-
//! batching iteration model (DESIGN.md §3.8).
//!
//! 1. SLO-by-construction: every composed iteration containing online
//!    decodes keeps its predicted latency within the TPOT budget.
//! 2. Chunk conservation: total prefilled tokens per request exactly cover
//!    the prompt — no lost or double-counted chunks across preemption,
//!    eviction, migration, and rescue churn (the core audits every prefill
//!    completion; the counter must stay 0).
//! 3. The headline trade: on a long-prompt + offline co-locate trace the
//!    chunked model serves offline work with zero discarded prefill while
//!    keeping the online SLO (p99 TPOT included); the exclusive-step
//!    baseline burns its offline attempts in truncation discard loops.

use std::collections::HashSet;

use ooco::config::{ChunkMode, ServingConfig};
use ooco::request::Class;
use ooco::scheduler::{
    Action, CoreConfig, Executor, Policy, SchedulerCore, VirtualExecutor,
};
use ooco::sim::{simulate, SimConfig};
use ooco::trace::datasets::{DatasetProfile, LengthProfile};
use ooco::trace::generator::{
    offline_trace, offline_trace_with_prefix, online_trace, PrefixProfile,
};
use ooco::trace::Trace;

/// Offline dataset with long prompts but short outputs, so offline decode
/// completes within test-sized horizons.
fn long_prompt_offline(mean: usize, max: usize) -> DatasetProfile {
    let mut ds = DatasetProfile::ooc_offline();
    ds.prompt = LengthProfile::new(mean as f64, 0.5, 512, max);
    ds.output = LengthProfile::new(120.0, 0.5, 8, 256);
    ds
}

fn run_core_with_log(
    trace: &Trace,
    cfg: CoreConfig,
) -> (SchedulerCore, Vec<Action>) {
    let horizon = trace.duration() + 600.0;
    let mut virt = VirtualExecutor::new(trace, horizon);
    virt.log = Some(Vec::new());
    let mut core = SchedulerCore::new(trace.requests.clone(), cfg);
    virt.run(&mut core).unwrap();
    (core, virt.log.unwrap())
}

/// §3.8 property: with chunking enabled, (a) the predicted latency of
/// every iteration containing online decodes stays within the TPOT
/// budget (Algorithm 2's per-iteration SLO enforcement), and (b) every
/// *composed* iteration whose chunk exceeds the 512-token progress floor
/// — i.e. every solver-chosen budget — stays within the headroom-reduced
/// TPOT budget the `chunk_budget` solver promises by construction.
#[test]
fn composed_online_iterations_stay_within_tpot() {
    let online = online_trace(DatasetProfile::azure_conv(), 0.3, 120.0, 61);
    let offline = offline_trace(long_prompt_offline(6000, 16384), 1.0, 120.0, 62);
    let trace = online.merge(offline);
    let online_ids: HashSet<u64> = trace
        .requests
        .iter()
        .filter(|r| r.class == Class::Online)
        .map(|r| r.id)
        .collect();
    let mut cfg = CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.serving.chunk_tokens = ChunkMode::Auto;
    let tpot = cfg.serving.slo.tpot;
    let chunk_bound = tpot * (1.0 - cfg.serving.sched.slo_margin);
    let (_, log) = run_core_with_log(&trace, cfg);
    let mut checked = 0usize;
    let mut solver_checked = 0usize;
    for a in &log {
        if let Action::StartStep {
            participants,
            prefill,
            predicted_latency,
            ..
        } = a
        {
            if participants.iter().any(|r| online_ids.contains(r)) {
                assert!(
                    *predicted_latency <= tpot * (1.0 + 1e-9),
                    "iteration with online decodes over budget: {} > {}",
                    predicted_latency,
                    tpot
                );
                checked += 1;
            }
            // Composed iterations above the progress floor carry a
            // solver-chosen chunk: the solver's bound must hold.
            let chunk_tokens: usize = prefill.iter().map(|s| s.tokens).sum();
            if chunk_tokens > 512 {
                assert!(
                    *predicted_latency <= chunk_bound * (1.0 + 1e-9),
                    "solver-budgeted composed iteration over bound: {} > {} ({chunk_tokens} chunk tokens)",
                    predicted_latency,
                    chunk_bound
                );
                solver_checked += 1;
            }
        }
    }
    assert!(checked > 50, "too few online decode iterations ({checked})");
    assert!(
        solver_checked > 50,
        "too few solver-budgeted composed iterations ({solver_checked})"
    );
}

/// §3.8 conservation property: across prefix hits, chunk-granular
/// preemption, capacity evictions, migration, and rescue churn, every
/// prefill completion lands its cursor exactly on the admission target —
/// the core's audit counter stays 0 and all online work still finishes.
#[test]
fn chunk_accounting_exact_under_churn() {
    let online = online_trace(DatasetProfile::azure_conv(), 0.5, 120.0, 71);
    let offline = offline_trace_with_prefix(
        long_prompt_offline(3000, 8000),
        1.5,
        120.0,
        PrefixProfile::FewShot {
            groups: 6,
            prefix_len: 800,
        },
        72,
    );
    let trace = online.merge(offline);
    for mode in [ChunkMode::Auto, ChunkMode::Fixed(1024)] {
        let mut cfg =
            CoreConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.serving.chunk_tokens = mode;
        // Squeeze KV so admissions, decode growth, and rescues churn:
        // weights ~15.2 GB, so ~45k KV tokens per instance.
        cfg.serving.hardware.mem_capacity = 18.5e9;
        let (core, log) = run_core_with_log(&trace, cfg);
        assert_eq!(
            core.cluster.chunk_accounting_errors, 0,
            "{mode:?}: lost or double-counted chunks"
        );
        // The run must actually have exercised the churn paths.
        assert!(
            core.cluster.preemptions > 0,
            "{mode:?}: no chunk-granular preemptions"
        );
        assert!(
            core.cluster.evictions
                + core.cluster.rescues
                + core.cluster.offloads
                + core.cluster.migrations
                > 0,
            "{mode:?}: no eviction/migration churn under squeezed memory"
        );
        // The stream really is chunked: some request needed > 1 segment.
        let mut seen: HashSet<u64> = HashSet::new();
        let mut resumed = false;
        for a in &log {
            if let Action::StartStep { prefill, .. } = a {
                for s in prefill {
                    resumed |= !seen.insert(s.req) && s.tokens > 0;
                }
            }
        }
        assert!(resumed, "{mode:?}: no multi-chunk prefill in the stream");
        // Every online request still finished despite the churn.
        for r in &core.cluster.requests {
            if r.class == Class::Online {
                assert!(
                    r.finished_at.is_some(),
                    "{mode:?}: online request {} unfinished",
                    r.id
                );
            }
        }
    }
}

/// The §3.8 acceptance comparison: long-prompt offline work co-located
/// with steady online traffic. Chunked iterations retain preempted
/// progress (zero discard) and serve the offline stream while the online
/// SLO — p99 TPOT included — holds; the exclusive-step baseline truncates
/// every offline attempt into a discard-and-recompute loop that starves
/// offline throughput.
#[test]
fn chunked_serves_long_prompts_where_exclusive_discards() {
    let duration = 180.0;
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.7, duration, 81);
    let offline =
        offline_trace(long_prompt_offline(10000, 16384), 0.4, duration, 82);
    let trace = online.merge(offline);

    let run = |mode: ChunkMode| {
        let mut cfg =
            SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
        cfg.serving.chunk_tokens = mode;
        cfg.drain_s = 600.0;
        simulate(&trace, &cfg)
    };
    let chunked = run(ChunkMode::Auto);
    let exclusive = run(ChunkMode::Off);
    let slo = ServingConfig::preset_7b().slo;

    // Chunked mode: online SLO holds, p99 TPOT inside the bound, no
    // prefill work ever discarded, and the long-prompt offline stream is
    // actually served.
    assert!(
        chunked.report.meets_slo(&slo),
        "chunked mode must keep the online SLO: {}",
        chunked.report.summary_line()
    );
    assert!(
        chunked.report.tpot.p99 <= slo.tpot * (1.0 + 1e-9),
        "chunked online p99 TPOT {} over bound {}",
        chunked.report.tpot.p99,
        slo.tpot
    );
    assert_eq!(chunked.chunk.preempted_work_discarded, 0);
    assert_eq!(chunked.chunk.accounting_errors, 0);
    assert!(
        chunked.report.offline_finished > 0,
        "chunked mode must finish long-prompt offline work: {}",
        chunked.report.summary_line()
    );

    // Exclusive mode: every online arrival mid-offline-prefill truncates
    // and discards the attempt — the co-located offline stream starves.
    assert!(
        exclusive.chunk.preempted_work_discarded > 0,
        "exclusive mode must discard truncated prefill work"
    );
    assert!(
        chunked.report.offline_token_throughput
            > 2.0 * exclusive.report.offline_token_throughput,
        "chunked offline throughput {} must dwarf exclusive {}",
        chunked.report.offline_token_throughput,
        exclusive.report.offline_token_throughput
    );
    assert!(
        chunked.chunk.preempted_work_retained > 0,
        "chunked preemptions must retain progress"
    );
}
