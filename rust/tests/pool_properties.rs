//! Property tests for the elastic pool manager (DESIGN.md §3.6).
//!
//! The four §3.6 invariants:
//! 1. **Instance-count conservation** — repartitioning repurposes
//!    instances, it never creates or destroys them: every
//!    `RepartitionPlan`'s currents and targets sum to the cluster size,
//!    and the final cluster is the size it started at.
//! 2. **No admissions to a draining instance** — between a
//!    `RoleChange{Drain}` and its `Flip`, the draining instance receives
//!    no gating admissions, no migration pulls, and no rescue/restore
//!    streams.
//! 3. **No online SLO violation caused solely by a role flip** — on a
//!    steady trace, the elastic policy (which does flip) stays within a
//!    hair of the static split's online violation rate.
//! 4. **Planner monotonicity** — more load never yields a smaller strict
//!    pool.

use ooco::config::{PoolPolicy, ServingConfig, SloSpec};
use ooco::perfmodel::PerfModel;
use ooco::pool::{min_strict_pool, PlannerInput};
use ooco::prop_assert;
use ooco::scheduler::{
    Action, CoreConfig, Executor, InstanceRef, Policy, RolePhase,
    SchedulerCore, TransferKind, VirtualExecutor,
};
use ooco::sim::{simulate, SimConfig};
use ooco::testutil::forall;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace, two_phase_trace};
use ooco::trace::Trace;

/// Memory-squeezed serving config: ~66k KV tokens per instance, so the
/// strict pool's capacity binds at test-scale loads (the
/// `bench_fast_preemption` idiom).
fn squeezed(total_relaxed: usize, total_strict: usize) -> ServingConfig {
    let mut serving = ServingConfig::preset_7b();
    serving.hardware.mem_capacity = 20e9;
    serving.cluster.relaxed_instances = total_relaxed;
    serving.cluster.strict_instances = total_strict;
    serving
}

/// Two-phase regime-change trace: heavy online first half (base 5 →
/// ≈ 7 req/s at azure-conv's mid-morning tide factor, forcing a 2-strict
/// plan under the squeezed memory), light second half (plan shrinks back
/// to 1), steady offline load throughout.
fn regime_change_trace(half_s: f64, seed: u64) -> Trace {
    two_phase_trace(
        DatasetProfile::azure_conv(),
        5.0,
        0.5,
        half_s,
        DatasetProfile::ooc_offline(),
        1.0,
        seed,
    )
}

/// Run an elastic core over the regime-change trace, returning the final
/// core and the full action stream.
fn elastic_run(policy: Policy, pool: PoolPolicy) -> (SchedulerCore, Vec<Action>) {
    let trace = regime_change_trace(120.0, 42);
    let mut serving = squeezed(3, 1);
    serving.pool = pool;
    let mut cfg = CoreConfig::new(serving, policy);
    cfg.seed = 11;
    let mut core = SchedulerCore::new(trace.requests.clone(), cfg);
    let mut ex = VirtualExecutor::new(&trace, trace.duration() + 300.0);
    ex.log = Some(Vec::new());
    ex.run(&mut core).unwrap();
    (core, ex.log.unwrap())
}

#[test]
fn repartitions_conserve_instance_count() {
    let (core, stream) = elastic_run(
        Policy::Ooco,
        PoolPolicy::Periodic {
            epoch_s: 20.0,
            headroom: 0.15,
        },
    );
    let mut plans = 0;
    for a in &stream {
        if let Action::RepartitionPlan {
            relaxed_current,
            strict_current,
            relaxed_target,
            strict_target,
            ..
        } = a
        {
            plans += 1;
            assert_eq!(relaxed_current + strict_current, 4, "{a:?}");
            assert_eq!(relaxed_target + strict_target, 4, "{a:?}");
            assert!(*strict_target >= 1 && *relaxed_target >= 1, "{a:?}");
        }
    }
    assert!(plans >= 2, "periodic policy must plan repeatedly ({plans})");
    // The regime change actually moved the boundary (both phases exist)...
    let flips = stream
        .iter()
        .filter(|a| {
            matches!(
                a,
                Action::RoleChange {
                    phase: RolePhase::Flip,
                    ..
                }
            )
        })
        .count();
    assert!(flips >= 1, "regime change must cause at least one flip");
    // ...and the cluster still has every instance it started with.
    assert_eq!(core.cluster.total_instances(), 4);
    assert_eq!(core.pool_report().flips as usize, flips);
}

#[test]
fn no_admissions_to_a_draining_instance() {
    let (_, stream) = elastic_run(
        Policy::Ooco,
        PoolPolicy::Periodic {
            epoch_s: 20.0,
            headroom: 0.15,
        },
    );
    // Track the draining instance between Drain and Flip announcements
    // (at most one transition is in flight at a time).
    let mut draining: Option<InstanceRef> = None;
    let mut saw_drain = false;
    for a in &stream {
        match a {
            Action::RoleChange {
                phase: RolePhase::Drain,
                inst,
                ..
            } => {
                assert!(draining.is_none(), "two drains in flight");
                draining = Some(*inst);
                saw_drain = true;
            }
            Action::RoleChange {
                phase: RolePhase::Flip,
                ..
            } => {
                draining = None;
            }
            Action::Admit { inst, .. } => {
                assert_ne!(
                    Some(InstanceRef::Relaxed(*inst)),
                    draining,
                    "gating admission onto a draining instance"
                );
            }
            Action::Migrate { to_strict, .. } => {
                assert_ne!(
                    Some(InstanceRef::Strict(*to_strict)),
                    draining,
                    "migration pull into a draining instance"
                );
            }
            Action::TransferStart { kind, .. } => {
                let dest = match kind {
                    TransferKind::Rescue { to_relaxed }
                    | TransferKind::Restore { to_relaxed } => {
                        Some(InstanceRef::Relaxed(*to_relaxed))
                    }
                    _ => None,
                };
                if let Some(dest) = dest {
                    assert_ne!(
                        Some(dest),
                        draining,
                        "KV streamed into a draining instance"
                    );
                }
            }
            _ => {}
        }
    }
    assert!(saw_drain, "scenario must exercise at least one drain");
}

/// Static vs elastic differential on a *steady* trace: the planner shrinks
/// the overprovisioned strict pool (so flips do happen), and the flips
/// alone must not cost online SLO attainment.
#[test]
fn role_flips_cause_no_online_slo_regression_on_steady_trace() {
    let ds = DatasetProfile::azure_conv();
    // Steady: base 2.0 -> ~2.9 req/s effective at the mid-morning tide
    // factor; one strict instance absorbs it, so the planner shrinks.
    let trace = online_trace(ds, 2.0, 300.0, 9).merge(offline_trace(
        DatasetProfile::ooc_offline(),
        0.5,
        300.0,
        10,
    ));

    let run = |pool: PoolPolicy| {
        let mut serving = squeezed(2, 2);
        serving.pool = pool;
        let mut cfg = SimConfig::new(serving, Policy::Ooco);
        cfg.seed = 5;
        simulate(&trace, &cfg)
    };
    let stat = run(PoolPolicy::Static);
    let elastic = run(PoolPolicy::Periodic {
        epoch_s: 30.0,
        headroom: 0.15,
    });

    assert!(
        elastic.pool.flips >= 1,
        "steady overprovisioned strict pool must shrink: {}",
        elastic.pool.summary_line()
    );
    assert_eq!(stat.pool.flips, 0);
    // Both runs serve online within the SLO regime; the elastic run's
    // violation rate may not exceed static's by more than noise.
    assert!(
        elastic.report.online_violation_rate
            <= stat.report.online_violation_rate + 0.02,
        "flip-induced SLO regression: elastic {:.4} vs static {:.4}",
        elastic.report.online_violation_rate,
        stat.report.online_violation_rate
    );
    // And the freed instance is real capacity: elastic offline throughput
    // is at least static's (strictly more whenever offline work queues).
    assert!(
        elastic.report.offline_token_throughput
            >= 0.95 * stat.report.offline_token_throughput,
        "elastic offline {:.1} vs static {:.1}",
        elastic.report.offline_token_throughput,
        stat.report.offline_token_throughput
    );
}

#[test]
fn planner_is_monotone_in_load() {
    let serving = ServingConfig::preset_7b();
    let pm = PerfModel::new(serving.model.clone(), serving.hardware.clone());
    let slo = SloSpec::default();
    forall(60, |r| {
        let total = 2 + r.below(7); // 2..=8 instances
        let headroom = 0.05 * r.below(8) as f64; // 0 .. 0.35
        let prompt = 100.0 + r.below(4000) as f64;
        let output = 10.0 + r.below(1000) as f64;
        let mut last = 0usize;
        let mut rate = 0.0;
        for _ in 0..8 {
            rate += r.below(200) as f64 / 10.0;
            let n = min_strict_pool(
                &pm,
                &slo,
                &PlannerInput {
                    online_rate: rate,
                    mean_prompt: prompt,
                    mean_output: output,
                    shared_kv_fraction: 0.0,
                    chunk_prefill_tokens: 0,
                },
                total,
                headroom,
            );
            prop_assert!(
                n >= last,
                "rate {rate}: pool shrank {last} -> {n} (total {total})"
            );
            prop_assert!(
                n >= 1 && n < total,
                "pool size {n} out of range (total {total})"
            );
            last = n;
        }
        Ok(())
    });
}
