//! Property tests for the KV transport subsystem (conservation, monotone
//! per-link completions, exactly-once cancellation) plus the simulator-level
//! acceptance claims: a bandwidth-constrained link produces measurable
//! transfer stall and lower migration throughput than an unconstrained one,
//! and recoverable fast preemption replaces discard-and-recompute evictions.

use ooco::config::{
    HardwareProfile, LinkSharing, ServingConfig, TransportSpec,
};
use ooco::prop_assert;
use ooco::scheduler::Policy;
use ooco::sim::{simulate, SimConfig, SimResult};
use ooco::testutil::forall;
use ooco::trace::datasets::DatasetProfile;
use ooco::trace::generator::{offline_trace, online_trace};
use ooco::trace::Trace;
use ooco::transport::{Progress, TransferKind, TransportEngine};
use ooco::util::rng::Pcg;

// ------------------------------------------------------------ unit props

fn random_spec(r: &mut Pcg) -> TransportSpec {
    let mut spec =
        TransportSpec::for_hardware(&HardwareProfile::ascend_910c());
    spec.pool.bandwidth = (r.below(1000) + 1) as f64 * 1e6;
    spec.host.bandwidth = (r.below(1000) + 1) as f64 * 1e6;
    spec.pool.latency = r.below(100) as f64 * 1e-6;
    spec.host.latency = r.below(100) as f64 * 1e-6;
    spec.pool.sharing = if r.below(2) == 0 {
        LinkSharing::Fifo
    } else {
        LinkSharing::FairShare
    };
    spec.host.sharing = if r.below(2) == 0 {
        LinkSharing::Fifo
    } else {
        LinkSharing::FairShare
    };
    spec.chunk_layers = r.below(28) + 1;
    spec
}

fn random_kind(r: &mut Pcg) -> TransferKind {
    match r.below(5) {
        0 => TransferKind::Dispatch { to_strict: 0 },
        1 => TransferKind::Migrate { to_strict: 0 },
        2 => TransferKind::Rescue { to_relaxed: 0 },
        3 => TransferKind::Offload,
        _ => TransferKind::Restore { to_relaxed: 0 },
    }
}

fn pop_earliest(
    events: &mut Vec<(f64, u64, u64, usize)>,
) -> Option<(f64, u64, u64, usize)> {
    if events.is_empty() {
        return None;
    }
    let mut best = 0usize;
    for i in 1..events.len() {
        if events[i].0 < events[best].0 {
            best = i;
        }
    }
    Some(events.swap_remove(best))
}

/// Conservation + monotonicity + exactly-once cancel, under random
/// interleavings of enqueue / chunk-completion / mid-flight cancel on both
/// links and both sharing disciplines.
#[test]
fn transport_conserves_bytes_and_orders_completions() {
    forall(60, |r| {
        let spec = random_spec(r);
        let mut eng = TransportEngine::new(&spec, 57344.0, 28);
        // (time, job, seq, link) of scheduled chunk completions.
        let mut events: Vec<(f64, u64, u64, usize)> = Vec::new();
        let mut last_done = [f64::NEG_INFINITY; 2];
        let mut live: Vec<u64> = Vec::new();
        let mut t = 0.0f64;
        let n_jobs = r.below(25) + 5;

        let handle = |eng: &mut TransportEngine,
                          events: &mut Vec<(f64, u64, u64, usize)>,
                          last_done: &mut [f64; 2],
                          t: &mut f64|
         -> Result<bool, String> {
            let Some((te, job, seq, link)) = pop_earliest(events) else {
                return Ok(false);
            };
            *t = t.max(te);
            match eng.on_chunk_done(*t, job, seq) {
                Progress::Stale => {
                    return Err(format!("unexpected stale chunk ({job},{seq})"))
                }
                Progress::Advanced { orders } => {
                    prop_assert!(
                        *t >= last_done[link],
                        "completions regressed on link {link}"
                    );
                    last_done[link] = *t;
                    for o in orders {
                        events.push((*t + o.duration, o.job, o.seq, o.link));
                    }
                }
                Progress::JobDone { job, orders } => {
                    prop_assert!(
                        *t >= last_done[job.link],
                        "completions regressed on link {}",
                        job.link
                    );
                    prop_assert!(
                        job.chunks_done == job.chunks,
                        "job finished early"
                    );
                    last_done[job.link] = *t;
                    for o in orders {
                        events.push((*t + o.duration, o.job, o.seq, o.link));
                    }
                }
            }
            Ok(true)
        };

        for i in 0..n_jobs {
            let kind = random_kind(r);
            let tokens = r.below(4000) + 1;
            let (id, orders) = eng.enqueue(t, i as u64, kind, tokens);
            live.push(id);
            for o in orders {
                events.push((t + o.duration, o.job, o.seq, o.link));
            }
            // Occasionally cancel a random job mid-flight; a second cancel
            // of the same job must never release resources again.
            if r.below(4) == 0 && !live.is_empty() {
                let victim = live[r.below(live.len())];
                if eng.cancel(victim).is_some() {
                    prop_assert!(
                        eng.cancel(victim).is_none(),
                        "double cancel released job {victim} twice"
                    );
                }
            }
            // Interleave: let a few chunks land between enqueues.
            for _ in 0..r.below(3) {
                handle(&mut eng, &mut events, &mut last_done, &mut t)?;
            }
        }
        // Drain everything.
        while handle(&mut eng, &mut events, &mut last_done, &mut t)? {}

        prop_assert!(
            eng.active_jobs() == 0,
            "jobs leaked: {}",
            eng.active_jobs()
        );
        prop_assert!(
            eng.in_flight_bytes().abs() < 1e-6,
            "in-flight bytes after drain"
        );
        let lhs = eng.bytes_enqueued;
        let rhs = eng.bytes_delivered + eng.bytes_cancelled;
        prop_assert!(
            (lhs - rhs).abs() <= 1e-6 * lhs.max(1.0),
            "bytes not conserved: enqueued {lhs} vs delivered+cancelled {rhs}"
        );
        Ok(())
    });
}

/// An uncontended chunked transfer takes exactly its ideal duration: the
/// chunking must not change total transfer time on an idle link.
#[test]
fn uncontended_transfer_matches_ideal_duration() {
    let mut spec =
        TransportSpec::for_hardware(&HardwareProfile::ascend_910c());
    spec.pool.latency = 0.0;
    let mut eng = TransportEngine::new(&spec, 57344.0, 28);
    let tokens = 1892usize;
    let (_, mut orders) =
        eng.enqueue(0.0, 0, TransferKind::Dispatch { to_strict: 0 }, tokens);
    let mut t = 0.0;
    let mut end = None;
    while let Some(o) = orders.pop() {
        t += o.duration;
        match eng.on_chunk_done(t, o.job, o.seq) {
            Progress::Stale => panic!("stale"),
            Progress::Advanced { orders: next } => orders.extend(next),
            Progress::JobDone { .. } => end = Some(t),
        }
    }
    let ideal = tokens as f64 * 57344.0 / spec.pool.bandwidth;
    let end = end.expect("job must complete");
    assert!(
        (end - ideal).abs() < 1e-9 * ideal.max(1.0),
        "chunked total {end} vs single-shot ideal {ideal}"
    );
    assert!(eng.links()[0].stall_s < 1e-9, "idle link must not stall");
}

// ------------------------------------------------- simulator-level claims

fn migration_workload(seed: u64) -> Trace {
    let online =
        online_trace(DatasetProfile::azure_conv(), 0.4, 600.0, seed);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 1.5, 600.0, seed + 1);
    online.merge(offline)
}

fn run_with_bandwidth(trace: &Trace, pool_bw: Option<f64>) -> SimResult {
    let mut cfg = SimConfig::new(ServingConfig::preset_7b(), Policy::Ooco);
    cfg.drain_s = 1200.0;
    cfg.seed = 7;
    if let Some(bw) = pool_bw {
        cfg.serving.transport.pool.bandwidth = bw;
    }
    simulate(trace, &cfg)
}

/// Acceptance criterion: constraining the interconnect produces measurable
/// transfer stall and lower migration throughput (offline tokens decoded on
/// strict nodes) than the unconstrained run — transfers no longer teleport.
#[test]
fn constrained_link_stalls_and_cuts_migration_throughput() {
    let trace = migration_workload(42);
    let unconstrained = run_with_bandwidth(&trace, None); // 25 GB/s default
    let constrained = run_with_bandwidth(&trace, Some(0.2e9)); // 125x less

    assert!(
        unconstrained.strict_offline_tokens > 0,
        "workload must exercise migration at all"
    );
    assert!(
        constrained.transport.stall_s > 1.0,
        "constrained link shows no measurable stall: {:.3}s",
        constrained.transport.stall_s
    );
    assert!(
        constrained.transport.stall_s > 10.0 * unconstrained.transport.stall_s,
        "stall must explode under the bandwidth cut: {:.3}s vs {:.3}s",
        constrained.transport.stall_s,
        unconstrained.transport.stall_s
    );
    assert!(
        constrained.strict_offline_tokens
            < unconstrained.strict_offline_tokens,
        "migration throughput must drop: {} vs {}",
        constrained.strict_offline_tokens,
        unconstrained.strict_offline_tokens
    );
    // Link utilization is visible and higher under constraint.
    let util = |r: &SimResult| r.transport.links[0].utilization;
    assert!(util(&constrained) > util(&unconstrained));
}

/// Recoverable fast preemption engages under memory pressure and replaces
/// discard-and-recompute: strictly fewer recompute evictions, with the KV
/// streamed out (rescues/offloads) and restart latencies recorded instead.
#[test]
fn recoverable_eviction_replaces_recompute_under_pressure() {
    // Shrink device memory so both pools fit only a few dozen requests:
    // eviction churn is constant.
    let mut serving = ServingConfig::preset_7b();
    serving.hardware.mem_capacity = 18e9;
    let online = online_trace(DatasetProfile::azure_conv(), 0.8, 400.0, 11);
    let offline =
        offline_trace(DatasetProfile::ooc_offline(), 4.0, 400.0, 12);
    let trace = online.merge(offline);

    let mut rec_cfg = SimConfig::new(serving.clone(), Policy::Ooco);
    rec_cfg.drain_s = 2000.0;
    let recoverable = simulate(&trace, &rec_cfg);

    let mut dis_cfg = SimConfig::new(serving, Policy::Ooco);
    dis_cfg.drain_s = 2000.0;
    dis_cfg.serving.transport.recoverable_eviction = false;
    dis_cfg.serving.transport.host_staging = false;
    let discard = simulate(&trace, &dis_cfg);

    assert!(
        discard.evictions > 0,
        "workload must force evictions ({} offline finished)",
        discard.report.offline_finished
    );
    assert!(
        recoverable.rescues + recoverable.offloads > 0,
        "fast preemption never engaged"
    );
    assert_eq!(discard.rescues, 0, "discard run must not rescue");
    assert!(
        recoverable.evictions < discard.evictions,
        "recoverable eviction must replace recompute: {} vs {}",
        recoverable.evictions,
        discard.evictions
    );
    assert!(
        recoverable.transport.restart_latency.count > 0,
        "no preemption-to-restart latencies recorded"
    );
    // Not recomputing prefills must not cost offline throughput.
    assert!(
        recoverable.report.offline_token_throughput
            >= 0.95 * discard.report.offline_token_throughput,
        "recoverable {} vs discard {}",
        recoverable.report.offline_token_throughput,
        discard.report.offline_token_throughput
    );
}
