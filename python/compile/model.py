"""Layer-2 JAX model: a tiny Qwen2.5-style decoder-only transformer.

Architecture mirrors the Qwen2.5 family evaluated in the paper (RMSNorm,
rotary position embeddings, grouped-query attention, SwiGLU MLP, tied LM
head) scaled down so the AOT artifacts execute quickly on the CPU PJRT
client. The perf-model experiments use the true 7B/72B dimensions (see
``rust/src/config``); this model exists to prove the full three-layer stack
composes end-to-end with real numerics (DESIGN.md §2, §6).

Both entry points are *functional*: the KV cache is an explicit argument and
result, because the rust coordinator owns cache residency (paged KV manager,
migration between instances) and the HLO executable must stay stateless.

Hot spots call the Layer-1 Pallas kernels:
  - linear projections -> :func:`compile.kernels.pallas_matmul`
  - prefill attention  -> :func:`compile.kernels.flash_prefill_attention`
  - decode attention   -> :func:`compile.kernels.decode_attention`

Weights are generated from a fixed seed and baked into the HLO as constants
by ``aot.py`` (no network => no real checkpoints; scheduling behaviour does
not depend on weight values — DESIGN.md §2).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import pallas_matmul, flash_prefill_attention, decode_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of the tiny serving model.

    ``hidden == q_heads * head_dim`` is assumed throughout. ``smax`` is the
    padded KV-cache length every request carries (prompt + generation room).
    """

    vocab: int = 512
    hidden: int = 256
    layers: int = 4
    q_heads: int = 8
    kv_heads: int = 2
    head_dim: int = 32
    ffn: int = 512
    smax: int = 448
    rope_theta: float = 10000.0

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    def as_dict(self):
        return dataclasses.asdict(self)


# Prefill sequence-length buckets and decode batch-size buckets the AOT step
# compiles. The rust engine rounds each request/batch up to the next bucket.
PREFILL_BUCKETS = (64, 128, 256, 384)
DECODE_BUCKETS = (1, 2, 4, 8, 16)


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic synthetic weights, scaled for stable f32 activations."""
    key = jax.random.PRNGKey(seed)
    n_mats = cfg.layers * 7 + 1
    keys = iter(jax.random.split(key, n_mats))

    def mat(shape, fan_in):
        return (jax.random.normal(next(keys), shape, jnp.float32)
                / jnp.sqrt(jnp.float32(fan_in)))

    params = {"embed": mat((cfg.vocab, cfg.hidden), cfg.hidden),
              "final_norm": jnp.ones((cfg.hidden,), jnp.float32),
              "layers": []}
    for _ in range(cfg.layers):
        params["layers"].append({
            "ln1": jnp.ones((cfg.hidden,), jnp.float32),
            "ln2": jnp.ones((cfg.hidden,), jnp.float32),
            "wq": mat((cfg.hidden, cfg.hidden), cfg.hidden),
            "wk": mat((cfg.hidden, cfg.kv_dim), cfg.hidden),
            "wv": mat((cfg.hidden, cfg.kv_dim), cfg.hidden),
            "wo": mat((cfg.hidden, cfg.hidden), cfg.hidden),
            "w_gate": mat((cfg.hidden, cfg.ffn), cfg.hidden),
            "w_up": mat((cfg.hidden, cfg.ffn), cfg.hidden),
            "w_down": mat((cfg.ffn, cfg.hidden), cfg.ffn),
        })
    return params


def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta):
    """Rotary embedding. x: [N, H, Dh]; positions: [N] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [N, half]
    cos = jnp.cos(angles)[:, None, :]  # [N, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def linear(x, w):
    """Projection via the Pallas GEMM kernel. x: [N, Din], w: [Din, Dout]."""
    return pallas_matmul(x, w)


def swiglu(x, layer):
    gate = linear(x, layer["w_gate"])
    up = linear(x, layer["w_up"])
    return linear(jax.nn.silu(gate) * up, layer["w_down"])


def _qkv(x, layer, cfg, positions):
    """Project + reshape + rope. x: [N, hidden] -> q [N,Hq,Dh], k/v [N,Hkv,Dh]."""
    n = x.shape[0]
    q = linear(x, layer["wq"]).reshape(n, cfg.q_heads, cfg.head_dim)
    k = linear(x, layer["wk"]).reshape(n, cfg.kv_heads, cfg.head_dim)
    v = linear(x, layer["wv"]).reshape(n, cfg.kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def prefill_fn(params, cfg: ModelConfig, tokens, length):
    """Prefill a single request padded to bucket length S.

    Args:
      params: weight pytree from :func:`init_params`.
      tokens: ``[S]`` int32, padded with arbitrary ids beyond ``length``.
      length: scalar int32 — valid token count, 1 <= length <= S.

    Returns:
      ``(logits[V], k_cache[L, Hkv, Smax, Dh], v_cache[L, Hkv, Smax, Dh])``
      where logits are taken at position ``length - 1`` (the first generated
      token's distribution) and cache rows >= length are zero.
    """
    s = tokens.shape[0]
    positions = jnp.arange(s, dtype=jnp.int32)
    valid = (positions < length)[:, None]                    # [S, 1]
    x = params["embed"][tokens]                              # [S, hidden]

    k_caches, v_caches = [], []
    for layer in params["layers"]:
        h = rms_norm(x, layer["ln1"])
        q, k, v = _qkv(h, layer, cfg, positions)
        attn = flash_prefill_attention(q, k, v, length)      # [S, Hq, Dh]
        attn = attn.reshape(s, cfg.hidden)
        x = x + linear(attn, layer["wo"])
        x = x + swiglu(rms_norm(x, layer["ln2"]), layer)

        # Zero padded rows, pad S -> Smax, to head-major cache layout.
        kz = jnp.where(valid[:, :, None], k, 0.0)            # [S, Hkv, Dh]
        vz = jnp.where(valid[:, :, None], v, 0.0)
        pad = ((0, cfg.smax - s), (0, 0), (0, 0))
        k_caches.append(jnp.transpose(jnp.pad(kz, pad), (1, 0, 2)))
        v_caches.append(jnp.transpose(jnp.pad(vz, pad), (1, 0, 2)))

    x = rms_norm(x, params["final_norm"])
    last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=0)  # [1, hidden]
    logits = linear(last, params["embed"].T)[0]              # [V]
    k_cache = jnp.stack(k_caches)                            # [L, Hkv, Smax, Dh]
    v_cache = jnp.stack(v_caches)
    return logits, k_cache, v_cache


def decode_fn(params, cfg: ModelConfig, tokens, positions, k_cache, v_cache):
    """One decode step for a batch of B requests.

    Args:
      tokens: ``[B]`` int32 — the most recent token of each request.
      positions: ``[B]`` int32 — the slot each token occupies (== current
        sequence length - 1); the new K/V pair is written there.
      k_cache, v_cache: ``[B, L, Hkv, Smax, Dh]`` — per-request-contiguous
        layout so the rust side assembles batches by concatenating each
        request's cache block.

    Returns:
      ``(logits[B, V], k_cache', v_cache')`` with caches updated in-place at
      ``positions``.
    """
    b = tokens.shape[0]
    x = params["embed"][tokens]                              # [B, hidden]

    write = jax.vmap(  # per-request scatter of one [Hkv, 1, Dh] row
        lambda cache, kv, pos: jax.lax.dynamic_update_slice(
            cache, kv[:, None, :], (0, pos, 0)),
        in_axes=(0, 0, 0))

    # PERF: collect per-layer updated caches and stack once at the end
    # instead of `k_cache.at[:, li].set(...)` per layer — the .at[].set form
    # copied the *entire* [B, L, Hkv, Smax, Dh] cache every layer (§Perf).
    k_layers, v_layers = [], []
    for li, layer in enumerate(params["layers"]):
        h = rms_norm(x, layer["ln1"])
        q, k, v = _qkv(h, layer, cfg, positions)             # q [B,Hq,Dh]
        kc = write(k_cache[:, li], k, positions)             # [B, Hkv, Smax, Dh]
        vc = write(v_cache[:, li], v, positions)
        k_layers.append(kc)
        v_layers.append(vc)
        attn = decode_attention(q, kc, vc, positions)        # [B, Hq, Dh]
        x = x + linear(attn.reshape(b, cfg.hidden), layer["wo"])
        x = x + swiglu(rms_norm(x, layer["ln2"]), layer)

    x = rms_norm(x, params["final_norm"])
    logits = linear(x, params["embed"].T)                    # [B, V]
    return logits, jnp.stack(k_layers, 1), jnp.stack(v_layers, 1)


def make_prefill(params, cfg: ModelConfig):
    """Close over weights (bakes them as HLO constants — test/debug only;
    ``as_hlo_text`` elides large constants, so AOT uses the *_flat variants)."""
    return functools.partial(prefill_fn, params, cfg)


def make_decode(params, cfg: ModelConfig):
    return functools.partial(decode_fn, params, cfg)


def flatten_params(params):
    """Deterministic (leaves, treedef, names) flattening of the weight pytree.

    The leaf order here defines both the trailing-parameter order of the AOT
    artifacts and the layout of ``weights.bin``; the rust runtime replays the
    same order from the manifest.
    """
    paths, treedef = jax.tree_util.tree_flatten_with_path(params)
    names = [jax.tree_util.keystr(p) for p, _ in paths]
    leaves = [leaf for _, leaf in paths]
    return leaves, treedef, names


def make_prefill_flat(treedef, cfg: ModelConfig):
    """Prefill entry point taking weights as trailing parameters.

    Signature: ``fn(tokens[S], length, *weight_leaves)`` — weights become HLO
    parameters 2..N, loaded once by the rust runtime as device buffers.
    """

    def fn(tokens, length, *leaves):
        params = jax.tree_util.tree_unflatten(treedef, leaves)
        return prefill_fn(params, cfg, tokens, length)

    return fn


def make_decode_flat(treedef, cfg: ModelConfig):
    """Decode entry point: ``fn(tokens[B], positions[B], k, v, *weight_leaves)``."""

    def fn(tokens, positions, k_cache, v_cache, *leaves):
        params = jax.tree_util.tree_unflatten(treedef, leaves)
        return decode_fn(params, cfg, tokens, positions, k_cache, v_cache)

    return fn
