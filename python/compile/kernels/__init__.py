"""Layer-1 Pallas kernels for the OOCO reproduction.

All kernels are lowered with ``interpret=True`` so they compile to plain HLO
ops executable on the CPU PJRT client (real-TPU Mosaic custom-calls cannot run
there — see DESIGN.md §3 Hardware-Adaptation). Correctness is asserted against
the pure-jnp oracles in :mod:`compile.kernels.ref`.
"""

from .gemm import pallas_matmul  # noqa: F401
from .attention import flash_prefill_attention, decode_attention  # noqa: F401
