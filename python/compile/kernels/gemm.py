"""Block-tiled Pallas GEMM kernel (the MXU hot path).

TPU mapping of the paper's GEMM operator (Table 3): the grid tiles the output
into ``(bm, bn)`` blocks and streams ``(bm, bk) x (bk, bn)`` tile pairs through
VMEM, accumulating into a VMEM scratch accumulator — the BlockSpec expression
of the HBM<->VMEM schedule a CUDA kernel would write with threadblocks.

VMEM footprint per grid step (f32):
    bm*bk + bk*bn + bm*bn  floats  =  (64*128 + 128*128 + 64*128)*4 B ≈ 160 KiB
comfortably under the ~16 MiB VMEM budget, leaving room for double-buffering.
Tile shapes are multiples of the (8, 128) f32 TPU tile so the MXU sees full
128-lane operands.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; numerics are validated against
:func:`compile.kernels.ref.ref_matmul`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile sizes. Chosen for the tiny serving model's layer shapes
# (hidden=256, ffn=512): every weight matrix divides evenly, and the shapes
# stay multiples of the f32 (8, 128) TPU tile.
BM, BN, BK = 64, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush at k == n_k-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def pallas_matmul(a, b, bm=BM, bn=BN, bk=BK):
    """Tiled matmul ``a[M,K] @ b[K,N] -> [M,N]`` via a Pallas kernel.

    Dimensions that do not divide the tile sizes are zero-padded up front and
    the result is sliced back; zero padding is exact for matmul. Tiles are
    clamped to the (padded) problem size so small shapes stay single-block.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)

    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    mp, kp = a.shape
    np_ = b.shape[1]
    n_k = kp // bk_

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm_, np_ // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=True,
    )(a, b)

    if pm or pn:
        out = out[:m, :n]
    return out
