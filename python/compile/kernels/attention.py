"""Pallas flash-attention kernels: prefill (causal, blocked online softmax)
and decode (single query over a padded KV cache).

TPU mapping of the paper's fused attention operator (Table 3 / Fig. 2): the
intermediate score matrix never touches HBM. The prefill kernel streams K/V
blocks through VMEM and keeps the online-softmax running statistics (row max
``m``, row sum ``l``) plus the output accumulator in VMEM scratch — exactly
the "on-chip buffers" role the paper assigns to the 910c's cache. The decode
kernel is a single-query variant whose score row fits in one block, masked by
the per-request cache position.

PERF (§Perf, EXPERIMENTS.md): both kernels are **head-vectorized** — one
grid step processes *all* attention heads, with GQA expansion done in-VMEM.
The first version gridded over heads too ``(B, Hq)`` / ``(Hq, S/bq, S/bkv)``;
collapsing the head dimension cut grid steps 8x and reduced a B=16 decode
step from 362 ms to 78 ms on the interpret-mode substrate. The same
restructuring is right for real TPUs: larger per-step work amortizes
grid/dispatch overhead, and the full-head block still fits VMEM comfortably:

  decode per grid step (f32):  q  Hq*Dh           =  8*32*4    =   1 KiB
                               kv 2*Hkv*Smax*Dh   =  2*2*448*32*4 = 229 KiB
                               expanded kv 2*Hq*Smax*Dh          = 917 KiB
  prefill per grid step:       q 64*8*32*4 = 64 KiB, k/v 2*16 KiB,
                               acc 64 KiB, m/l 4 KiB
all far below the ~16 MiB VMEM budget.

All calls use ``interpret=True`` (see gemm.py for why); oracles in ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# Default block sizes along the query / key sequence dimensions.
BQ, BKV = 64, 64


def _prefill_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, n_kv, bq, bkv, group, scale,
):
    """Grid step (qi, ki): fold one K/V block into q-block qi, all heads."""
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                      # [bq, Hq, Dh]
    k = jnp.repeat(k_ref[...], group, axis=1)  # [bkv, Hq, Dh] (GQA in VMEM)
    v = jnp.repeat(v_ref[...], group, axis=1)
    # Scores for all heads at once: [Hq, bq, bkv].
    s = jnp.einsum("qhd,khd->hqk", q, k) * scale

    # Causal + valid-length mask in global coordinates.
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.logical_and(k_pos <= q_pos, k_pos < len_ref[0])[None, :, :]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                 # [Hq, bq]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # exp() of NEG_INF-masked rows underflows to 0 — no NaNs.
    p = jnp.exp(s - m_new[:, :, None])  # [Hq, bq, bkv]
    alpha = jnp.exp(m_prev - m_new)     # [Hq, bq]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, :, None] + jnp.einsum(
        "hqk,khd->hqd", p, v
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _flush():
        # Fully-masked rows (padding beyond `length`) have l == 0; emit 0s.
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = acc_ref[...] / safe[:, :, None]     # [Hq, bq, Dh]
        o_ref[...] = jnp.transpose(out, (1, 0, 2))  # [bq, Hq, Dh]


@functools.partial(jax.jit, static_argnames=("bq", "bkv"))
def flash_prefill_attention(q, k, v, length, bq=BQ, bkv=BKV):
    """Causal masked GQA flash attention for the prefill phase.

    Args:
      q: ``[S, Hq, Dh]`` (padded to the bucket length S).
      k, v: ``[S, Hkv, Dh]``.
      length: scalar int32 — number of valid tokens.
      bq, bkv: query/key block sizes (clamped to S).

    Returns:
      ``[S, Hq, Dh]``; rows >= length attend over the valid prefix — they are
      garbage-but-finite (matching the ref oracle) and callers mask them out
      (the L2 model zeroes padded KV rows before caching).
    """
    s, hq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    bq_, bkv_ = min(bq, s), min(bkv, s)
    assert s % bq_ == 0 and s % bkv_ == 0, f"S={s} must divide blocks {bq_},{bkv_}"
    n_kv = s // bkv_
    scale = 1.0 / (dh ** 0.5)
    len_arr = jnp.reshape(length.astype(jnp.int32), (1,))

    return pl.pallas_call(
        functools.partial(
            _prefill_kernel,
            n_kv=n_kv,
            bq=bq_,
            bkv=bkv_,
            group=group,
            scale=scale,
        ),
        grid=(s // bq_, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda qi, ki: (0,)),
            pl.BlockSpec((bq_, hq, dh), lambda qi, ki: (qi, 0, 0)),
            pl.BlockSpec((bkv_, hkv, dh), lambda qi, ki: (ki, 0, 0)),
            pl.BlockSpec((bkv_, hkv, dh), lambda qi, ki: (ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq_, hq, dh), lambda qi, ki: (qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, hq, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hq, bq_), jnp.float32),      # running max m
            pltpu.VMEM((hq, bq_), jnp.float32),      # running sum l
            pltpu.VMEM((hq, bq_, dh), jnp.float32),  # output accumulator
        ],
        interpret=True,
    )(len_arr, q, k, v)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, smax, scale, group):
    """Grid step (b,): request b's single query row, all heads at once."""
    q = q_ref[0]                                # [Hq, Dh]
    k = jnp.repeat(k_ref[0], group, axis=0)     # [Hq, Smax, Dh]
    v = jnp.repeat(v_ref[0], group, axis=0)
    s = jnp.einsum("hd,hsd->hs", q, k) * scale  # [Hq, Smax]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, smax), 1)
    s = jnp.where(idx <= pos_ref[0], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o_ref[0] = jnp.einsum("hs,hsd->hd", p, v) / jnp.sum(p, axis=-1, keepdims=True)


@jax.jit
def decode_attention(q, k_cache, v_cache, positions):
    """Single-token GQA attention over padded KV caches (decode phase).

    Args:
      q: ``[B, Hq, Dh]``.
      k_cache, v_cache: ``[B, Hkv, Smax, Dh]``.
      positions: ``[B]`` int32 — request b attends to slots 0..positions[b].

    Returns:
      ``[B, Hq, Dh]``.
    """
    b, hq, dh = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)

    return pl.pallas_call(
        functools.partial(_decode_kernel, smax=smax, scale=scale, group=group),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda bb: (bb,)),
            pl.BlockSpec((1, hq, dh), lambda bb: (bb, 0, 0)),
            pl.BlockSpec((1, hkv, smax, dh), lambda bb: (bb, 0, 0, 0)),
            pl.BlockSpec((1, hkv, smax, dh), lambda bb: (bb, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, dh), lambda bb: (bb, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, dh), jnp.float32),
        interpret=True,
    )(positions.astype(jnp.int32), q, k_cache, v_cache)
