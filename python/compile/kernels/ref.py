"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are tested against (pytest +
hypothesis sweeps in ``python/tests``). They are deliberately written in the
most obvious way possible — no tiling, no online softmax — so a disagreement
always implicates the kernel.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_matmul(a, b):
    """Plain f32 matmul oracle: ``a[M,K] @ b[K,N] -> [M,N]``."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def ref_prefill_attention(q, k, v, length):
    """Causal + valid-length masked multi-head attention (prefill phase).

    Args:
      q: ``[S, Hq, Dh]`` query tensor (padded to bucket length S).
      k: ``[S, Hkv, Dh]`` key tensor.
      v: ``[S, Hkv, Dh]`` value tensor.
      length: scalar int32, number of valid tokens (<= S).

    Returns:
      ``[S, Hq, Dh]`` attention output. Rows >= length are garbage-but-finite
      (they attend over the valid prefix); callers mask them out.
    """
    s, hq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    # Expand KV heads to match Q heads (GQA).
    k = jnp.repeat(k, group, axis=1)  # [S, Hq, Dh]
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    # [Hq, S, S] scores
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    causal = ki <= qi
    valid = ki < length
    mask = jnp.logical_and(causal, valid)[None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", probs, v)


def ref_decode_attention(q, k_cache, v_cache, positions):
    """Single-token (decode phase) attention over a padded KV cache.

    Args:
      q: ``[B, Hq, Dh]`` one query token per request.
      k_cache: ``[B, Hkv, Smax, Dh]`` padded key cache.
      v_cache: ``[B, Hkv, Smax, Dh]`` padded value cache.
      positions: ``[B]`` int32; request b attends to cache slots
        ``0..positions[b]`` inclusive (its own freshly-written token included).

    Returns:
      ``[B, Hq, Dh]``.
    """
    b, hq, dh = q.shape
    hkv = k_cache.shape[1]
    group = hq // hkv
    k = jnp.repeat(k_cache, group, axis=1)  # [B, Hq, Smax, Dh]
    v = jnp.repeat(v_cache, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    smax = k_cache.shape[2]
    valid = jnp.arange(smax)[None, None, :] <= positions[:, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", probs, v)
