"""AOT lowering: JAX model -> HLO *text* artifacts + weights.bin + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Weights are NOT baked as constants — ``as_hlo_text`` elides large constants
to ``{...}`` which the text parser reads back as zeros (verified), and full
constant printing costs ~36 MB per artifact. Instead every weight leaf is a
trailing HLO parameter, and the raw f32 values are written once to
``weights.bin``; the rust runtime uploads them as device-resident PJRT
buffers at startup and passes them to every ``execute_b`` call.

Lowering uses ``return_tuple=True`` so every artifact returns one tuple the
rust side unwraps with ``to_tuple()``.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target). Artifacts:

    prefill_s{S}.hlo.txt  (tokens[S] i32, length[] i32, *weights)
                          -> (logits[V], k[L,Hkv,Smax,Dh], v[L,Hkv,Smax,Dh])
    decode_b{B}.hlo.txt   (tokens[B] i32, positions[B] i32,
                           k[B,L,Hkv,Smax,Dh], v[B,L,Hkv,Smax,Dh], *weights)
                          -> (logits[B,V], k', v')
    weights.bin           little-endian f32 leaves in manifest order
    manifest.json         model config, buckets, weight specs, file map
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    DECODE_BUCKETS,
    PREFILL_BUCKETS,
    ModelConfig,
    flatten_params,
    init_params,
    make_decode_flat,
    make_prefill_flat,
)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _weight_specs(leaves):
    return [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]


def lower_prefill(treedef, leaves, cfg: ModelConfig, s: int) -> str:
    fn = make_prefill_flat(treedef, cfg)
    tok = jax.ShapeDtypeStruct((s,), jnp.int32)
    length = jax.ShapeDtypeStruct((), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(tok, length, *_weight_specs(leaves)))


def lower_decode(treedef, leaves, cfg: ModelConfig, b: int) -> str:
    fn = make_decode_flat(treedef, cfg)
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    kv = jax.ShapeDtypeStruct(
        (b, cfg.layers, cfg.kv_heads, cfg.smax, cfg.head_dim), jnp.float32
    )
    return to_hlo_text(
        jax.jit(fn).lower(tok, pos, kv, kv, *_weight_specs(leaves))
    )


def write_weights(leaves, names, out_dir):
    """Raw little-endian f32 blob + per-leaf specs (name, shape, offsets)."""
    specs = []
    offset = 0
    path = os.path.join(out_dir, "weights.bin")
    with open(path, "wb") as f:
        for name, leaf in zip(names, leaves):
            arr = np.asarray(leaf, dtype="<f4")
            f.write(arr.tobytes())
            specs.append({
                "name": name,
                "shape": list(arr.shape),
                "offset_bytes": offset,
                "num_elements": int(arr.size),
            })
            offset += arr.nbytes
    return specs, offset, path


def _inputs_fingerprint() -> str:
    """Hash of the compile-path sources, recorded for staleness checks."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, filenames in sorted(os.walk(here)):
        for name in sorted(filenames):
            if name.endswith(".py"):
                with open(os.path.join(root, name), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--only", default=None,
        help="comma-separated artifact stems to rebuild (e.g. decode_b4)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = ModelConfig()
    params = init_params(cfg, seed=args.seed)
    leaves, treedef, names = flatten_params(params)
    only = set(args.only.split(",")) if args.only else None

    files = {}
    for s in PREFILL_BUCKETS:
        stem = f"prefill_s{s}"
        files[stem] = stem + ".hlo.txt"
        if only and stem not in only:
            continue
        text = lower_prefill(treedef, leaves, cfg, s)
        path = os.path.join(args.out_dir, files[stem])
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for b in DECODE_BUCKETS:
        stem = f"decode_b{b}"
        files[stem] = stem + ".hlo.txt"
        if only and stem not in only:
            continue
        text = lower_decode(treedef, leaves, cfg, b)
        path = os.path.join(args.out_dir, files[stem])
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    weight_specs, total_bytes, wpath = write_weights(leaves, names, args.out_dir)
    print(f"wrote {wpath} ({total_bytes} bytes, {len(weight_specs)} leaves)")

    manifest = {
        "format": "hlo-text",
        "seed": args.seed,
        "model": cfg.as_dict(),
        "prefill_buckets": list(PREFILL_BUCKETS),
        "decode_buckets": list(DECODE_BUCKETS),
        "kv_cache_shape_per_request": [
            cfg.layers, cfg.kv_heads, cfg.smax, cfg.head_dim
        ],
        "weights_file": "weights.bin",
        "weights": weight_specs,
        "files": files,
        "inputs_fingerprint": _inputs_fingerprint(),
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
