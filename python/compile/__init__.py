"""OOCO build-time compile package: L2 JAX model + L1 Pallas kernels + AOT.

This package runs only during ``make artifacts``; nothing here is imported on
the rust request path.
"""
