"""AOT pipeline tests: HLO text emission, weights blob, manifest integrity."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import ModelConfig, flatten_params, init_params


@pytest.fixture(scope="module")
def small():
    cfg = ModelConfig(vocab=64, hidden=64, layers=2, q_heads=4, kv_heads=2,
                      head_dim=16, ffn=128, smax=96)
    params = init_params(cfg, seed=0)
    leaves, treedef, names = flatten_params(params)
    return cfg, leaves, treedef, names


class TestLowering:
    def test_prefill_hlo_text(self, small):
        cfg, leaves, treedef, _ = small
        text = aot.lower_prefill(treedef, leaves, cfg, s=32)
        assert "ENTRY" in text
        assert "s32[32]" in text              # tokens parameter
        assert f"f32[{cfg.vocab}]" in text    # logits result
        # weights travel as parameters, never as elided constants
        assert "constant({...}" not in text

    def test_decode_hlo_text(self, small):
        cfg, leaves, treedef, _ = small
        text = aot.lower_decode(treedef, leaves, cfg, b=2)
        assert "ENTRY" in text
        assert "s32[2]" in text
        kv = f"f32[2,{cfg.layers},{cfg.kv_heads},{cfg.smax},{cfg.head_dim}]"
        assert kv in text

    def test_parameter_count(self, small):
        cfg, leaves, treedef, _ = small
        text = aot.lower_prefill(treedef, leaves, cfg, s=32)
        entry = text[text.index("ENTRY"):]
        n_params = entry.count(" parameter(")
        assert n_params == 2 + len(leaves)


class TestWeightsBlob:
    def test_roundtrip(self, small, tmp_path):
        cfg, leaves, treedef, names = small
        specs, total, path = aot.write_weights(leaves, names, str(tmp_path))
        assert os.path.getsize(path) == total
        blob = np.fromfile(path, dtype="<f4")
        for spec, leaf in zip(specs, leaves):
            off = spec["offset_bytes"] // 4
            got = blob[off:off + spec["num_elements"]].reshape(spec["shape"])
            np.testing.assert_array_equal(got, np.asarray(leaf))

    def test_specs_are_contiguous(self, small, tmp_path):
        cfg, leaves, treedef, names = small
        specs, total, _ = aot.write_weights(leaves, names, str(tmp_path))
        off = 0
        for s in specs:
            assert s["offset_bytes"] == off
            off += s["num_elements"] * 4
        assert off == total

    def test_names_recorded(self, small, tmp_path):
        cfg, leaves, treedef, names = small
        specs, _, _ = aot.write_weights(leaves, names, str(tmp_path))
        assert [s["name"] for s in specs] == names
        assert "['embed']" in names[0]


class TestManifest:
    def test_fingerprint_stable(self):
        assert aot._inputs_fingerprint() == aot._inputs_fingerprint()

    def test_built_manifest_matches_artifacts(self):
        """If `make artifacts` has run, the manifest must describe the files."""
        art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        mpath = os.path.join(art, "manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("artifacts not built")
        m = json.load(open(mpath))
        assert m["format"] == "hlo-text"
        for stem, fname in m["files"].items():
            assert os.path.exists(os.path.join(art, fname)), fname
        wsize = os.path.getsize(os.path.join(art, m["weights_file"]))
        assert wsize == sum(w["num_elements"] * 4 for w in m["weights"])
        cfg = m["model"]
        assert cfg["hidden"] == cfg["q_heads"] * cfg["head_dim"]
        assert m["kv_cache_shape_per_request"] == [
            cfg["layers"], cfg["kv_heads"], cfg["smax"], cfg["head_dim"]
        ]
