"""Shared fixtures for the compile-path test suite."""

import numpy as np
import pytest

from compile.model import ModelConfig, init_params


@pytest.fixture(scope="session")
def cfg():
    return ModelConfig()


@pytest.fixture(scope="session")
def params(cfg):
    return init_params(cfg, seed=0)


@pytest.fixture()
def rng():
    return np.random.default_rng(1234)
