"""Layer-2 model tests: shapes, prefill/decode consistency, invariances."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    decode_fn,
    flatten_params,
    init_params,
    make_decode_flat,
    make_prefill_flat,
    prefill_fn,
)


@pytest.fixture(scope="module")
def jitted(cfg, params):
    pf = jax.jit(lambda t, l: prefill_fn(params, cfg, t, l))
    df = jax.jit(lambda t, p, k, v: decode_fn(params, cfg, t, p, k, v))
    return pf, df


def _toks(rng, cfg, n):
    return jnp.asarray(rng.integers(0, cfg.vocab, size=n), jnp.int32)


class TestShapes:
    def test_prefill_shapes(self, cfg, jitted, rng):
        pf, _ = jitted
        logits, kc, vc = pf(_toks(rng, cfg, 64), jnp.int32(20))
        assert logits.shape == (cfg.vocab,)
        want_kv = (cfg.layers, cfg.kv_heads, cfg.smax, cfg.head_dim)
        assert kc.shape == want_kv and vc.shape == want_kv

    def test_decode_shapes(self, cfg, jitted, rng):
        _, df = jitted
        b = 3
        kv_shape = (b, cfg.layers, cfg.kv_heads, cfg.smax, cfg.head_dim)
        kc = jnp.zeros(kv_shape, jnp.float32)
        vc = jnp.zeros(kv_shape, jnp.float32)
        logits, kc2, vc2 = df(
            _toks(rng, cfg, b), jnp.asarray([0, 1, 2], jnp.int32), kc, vc
        )
        assert logits.shape == (b, cfg.vocab)
        assert kc2.shape == kv_shape and vc2.shape == kv_shape

    def test_prefill_cache_rows_beyond_length_zero(self, cfg, jitted, rng):
        pf, _ = jitted
        _, kc, vc = pf(_toks(rng, cfg, 64), jnp.int32(13))
        assert np.all(np.asarray(kc[:, :, 13:]) == 0.0)
        assert np.all(np.asarray(vc[:, :, 13:]) == 0.0)
        assert np.any(np.asarray(kc[:, :, :13]) != 0.0)


class TestConsistency:
    @pytest.mark.parametrize("length", [1, 7, 33, 63])
    def test_decode_matches_longer_prefill(self, cfg, jitted, rng, length):
        """prefill(L)+decode(token L) logits == prefill(L+1) logits."""
        pf, df = jitted
        toks = _toks(rng, cfg, 64)
        want, _, _ = pf(toks, jnp.int32(length + 1))
        _, kc, vc = pf(toks, jnp.int32(length))
        got, _, _ = df(
            toks[length:length + 1],
            jnp.asarray([length], jnp.int32),
            kc[None],
            vc[None],
        )
        np.testing.assert_allclose(got[0], want, rtol=1e-3, atol=1e-3)

    def test_two_decode_steps_match_prefill(self, cfg, jitted, rng):
        """prefill(L) + two decode steps == prefill(L+2)."""
        pf, df = jitted
        toks = _toks(rng, cfg, 64)
        length = 10
        want, _, _ = pf(toks, jnp.int32(length + 2))
        _, kc, vc = pf(toks, jnp.int32(length))
        kb, vb = kc[None], vc[None]
        _, kb, vb = df(toks[length:length + 1],
                       jnp.asarray([length], jnp.int32), kb, vb)
        got, _, _ = df(toks[length + 1:length + 2],
                       jnp.asarray([length + 1], jnp.int32), kb, vb)
        np.testing.assert_allclose(got[0], want, rtol=1e-3, atol=1e-3)

    def test_batched_decode_matches_single(self, cfg, jitted, rng):
        """A request's decode output is identical alone or inside a batch."""
        pf, df = jitted
        toks_a, toks_b = _toks(rng, cfg, 64), _toks(rng, cfg, 64)
        _, ka, va = pf(toks_a, jnp.int32(11))
        _, kb, vb = pf(toks_b, jnp.int32(29))
        single, _, _ = df(toks_a[11:12], jnp.asarray([11], jnp.int32),
                          ka[None], va[None])
        batched, _, _ = df(
            jnp.concatenate([toks_a[11:12], toks_b[29:30]]),
            jnp.asarray([11, 29], jnp.int32),
            jnp.stack([ka, kb]),
            jnp.stack([va, vb]),
        )
        np.testing.assert_allclose(batched[0], single[0], rtol=1e-4, atol=1e-4)


class TestInvariances:
    def test_padding_tokens_do_not_matter(self, cfg, jitted, rng):
        pf, _ = jitted
        toks = _toks(rng, cfg, 64)
        length = jnp.int32(17)
        base, kc1, _ = pf(toks, length)
        toks2 = toks.at[17:].set((toks[17:] + 101) % cfg.vocab)
        pert, kc2, _ = pf(toks2, length)
        np.testing.assert_allclose(base, pert, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(kc1, kc2, rtol=1e-5, atol=1e-5)

    def test_deterministic(self, cfg, jitted, rng):
        pf, _ = jitted
        toks = _toks(rng, cfg, 64)
        a, _, _ = pf(toks, jnp.int32(30))
        b, _, _ = pf(toks, jnp.int32(30))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_init_params_seed_reproducible(self, cfg):
        p1 = init_params(cfg, seed=7)
        p2 = init_params(cfg, seed=7)
        l1, _, _ = flatten_params(p1)
        l2, _, _ = flatten_params(p2)
        for a, b in zip(l1, l2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_different_seed_different_weights(self, cfg):
        l1, _, _ = flatten_params(init_params(cfg, seed=0))
        l2, _, _ = flatten_params(init_params(cfg, seed=1))
        assert any(not np.allclose(a, b) for a, b in zip(l1, l2))


class TestFlatEntryPoints:
    def test_flat_prefill_matches_closure(self, cfg, params, rng):
        leaves, treedef, names = flatten_params(params)
        assert len(names) == len(leaves) == 2 + cfg.layers * 9
        flat = jax.jit(make_prefill_flat(treedef, cfg))
        toks = _toks(rng, cfg, 64)
        want, wk, wv = jax.jit(
            lambda t, l: prefill_fn(params, cfg, t, l))(toks, jnp.int32(21))
        got, gk, gv = flat(toks, jnp.int32(21), *leaves)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        np.testing.assert_allclose(gk, wk, rtol=1e-6)

    def test_flat_decode_matches_closure(self, cfg, params, rng):
        leaves, treedef, _ = flatten_params(params)
        flat = jax.jit(make_decode_flat(treedef, cfg))
        b = 2
        kv_shape = (b, cfg.layers, cfg.kv_heads, cfg.smax, cfg.head_dim)
        kc = jnp.asarray(rng.normal(size=kv_shape), jnp.float32)
        vc = jnp.asarray(rng.normal(size=kv_shape), jnp.float32)
        toks = _toks(rng, cfg, b)
        pos = jnp.asarray([4, 9], jnp.int32)
        want, _, _ = jax.jit(
            lambda t, p, k, v: decode_fn(params, cfg, t, p, k, v)
        )(toks, pos, kc, vc)
        got, _, _ = flat(toks, pos, kc, vc, *leaves)
        np.testing.assert_allclose(got, want, rtol=1e-6)


class TestSmallConfig:
    """The model must be correct for other dimension choices too."""

    def test_tiny_config_consistency(self):
        cfg = ModelConfig(vocab=64, hidden=64, layers=2, q_heads=4,
                          kv_heads=2, head_dim=16, ffn=128, smax=96)
        params = init_params(cfg, seed=3)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, size=32), jnp.int32)
        pf = jax.jit(lambda t, l: prefill_fn(params, cfg, t, l))
        df = jax.jit(lambda t, p, k, v: decode_fn(params, cfg, t, p, k, v))
        want, _, _ = pf(toks, jnp.int32(6))
        _, kc, vc = pf(toks, jnp.int32(5))
        got, _, _ = df(toks[5:6], jnp.asarray([5], jnp.int32),
                       kc[None], vc[None])
        np.testing.assert_allclose(got[0], want, rtol=1e-3, atol=1e-3)
