"""Pallas GEMM kernel vs the pure-jnp oracle (hypothesis shape sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_matmul
from compile.kernels.ref import ref_matmul

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),          # degenerate
        (8, 8, 8),          # single sub-tile
        (64, 128, 128),     # exactly one (BM, BK, BN) tile
        (128, 256, 256),    # multi-tile, exact division
        (100, 70, 130),     # ragged -> padding path
        (1, 256, 512),      # decode-like single row
        (384, 256, 64),     # prefill-like tall-skinny
    ],
)
def test_matmul_matches_ref(rng, m, k, n):
    a, b = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(pallas_matmul(a, b), ref_matmul(a, b), **TOL)


def test_matmul_identity(rng):
    a = _rand(rng, 64, 64)
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(pallas_matmul(a, eye), a, **TOL)


def test_matmul_zeros(rng):
    a = _rand(rng, 32, 48)
    z = jnp.zeros((48, 16), jnp.float32)
    assert np.all(np.asarray(pallas_matmul(a, z)) == 0.0)


def test_matmul_custom_tiles(rng):
    """Non-default tile sizes must not change the result."""
    a, b = _rand(rng, 96, 96), _rand(rng, 96, 96)
    want = ref_matmul(a, b)
    for bm, bn, bk in [(16, 16, 16), (32, 96, 48), (96, 32, 96)]:
        got = pallas_matmul(a, b, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    np.testing.assert_allclose(pallas_matmul(a, b), ref_matmul(a, b), **TOL)


@settings(max_examples=8, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**31 - 1))
def test_matmul_scale_equivariance(scale, seed):
    """(s*A) @ B == s * (A @ B) through the kernel (linearity)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(32, 40)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(40, 24)), jnp.float32)
    got = pallas_matmul(a * scale, b)
    want = np.asarray(pallas_matmul(a, b)) * scale
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale)
