"""Pallas attention kernels vs pure-jnp oracles.

Prefill: blocked online-softmax flash kernel, causal + valid-length mask.
Decode: single-query kernel over padded KV caches with per-request positions.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention, flash_prefill_attention
from compile.kernels.ref import ref_decode_attention, ref_prefill_attention

TOL = dict(rtol=2e-5, atol=2e-5)
HQ, HKV, DH = 8, 2, 32


def _qkv(rng, s):
    q = jnp.asarray(rng.normal(size=(s, HQ, DH)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, HKV, DH)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, HKV, DH)), jnp.float32)
    return q, k, v


class TestPrefill:
    @pytest.mark.parametrize("s", [32, 64, 128])
    @pytest.mark.parametrize("frac", [0.1, 0.5, 1.0])
    def test_matches_ref(self, rng, s, frac):
        q, k, v = _qkv(rng, s)
        length = jnp.int32(max(1, int(s * frac)))
        out = flash_prefill_attention(q, k, v, length)
        want = ref_prefill_attention(q, k, v, length)
        lv = int(length)
        np.testing.assert_allclose(out[:lv], want[:lv], **TOL)

    def test_padding_rows_finite(self, rng):
        """Rows >= length attend the valid prefix: garbage-but-finite, no NaNs."""
        q, k, v = _qkv(rng, 64)
        out = flash_prefill_attention(q, k, v, jnp.int32(10))
        assert np.all(np.isfinite(np.asarray(out)))

    def test_length_one(self, rng):
        """A single valid token attends only to itself -> output == its V."""
        q, k, v = _qkv(rng, 64)
        out = flash_prefill_attention(q, k, v, jnp.int32(1))
        group = HQ // HKV
        want = np.repeat(np.asarray(v[0]), group, axis=0)  # [HQ, DH]
        np.testing.assert_allclose(out[0], want, **TOL)

    def test_causality(self, rng):
        """Changing token t's K/V must not affect outputs at positions < t."""
        q, k, v = _qkv(rng, 64)
        length = jnp.int32(40)
        base = flash_prefill_attention(q, k, v, length)
        k2 = k.at[30].set(k[30] + 100.0)
        v2 = v.at[30].set(v[30] - 100.0)
        pert = flash_prefill_attention(q, k2, v2, length)
        np.testing.assert_allclose(base[:30], pert[:30], **TOL)
        assert not np.allclose(base[30:40], pert[30:40])

    def test_block_size_invariance(self, rng):
        q, k, v = _qkv(rng, 128)
        length = jnp.int32(100)
        a = flash_prefill_attention(q, k, v, length, bq=64, bkv=64)
        b = flash_prefill_attention(q, k, v, length, bq=32, bkv=128)
        c = flash_prefill_attention(q, k, v, length, bq=128, bkv=16)
        np.testing.assert_allclose(a[:100], b[:100], **TOL)
        np.testing.assert_allclose(a[:100], c[:100], **TOL)

    @settings(max_examples=10, deadline=None)
    @given(
        s_pow=st.integers(5, 7),
        length=st.integers(1, 128),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property(self, s_pow, length, seed):
        s = 2 ** s_pow
        length = min(length, s)
        rng = np.random.default_rng(seed)
        q, k, v = _qkv(rng, s)
        out = flash_prefill_attention(q, k, v, jnp.int32(length))
        want = ref_prefill_attention(q, k, v, jnp.int32(length))
        np.testing.assert_allclose(out[:length], want[:length], **TOL)


class TestDecode:
    def _cache(self, rng, b, smax):
        q = jnp.asarray(rng.normal(size=(b, HQ, DH)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(b, HKV, smax, DH)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(b, HKV, smax, DH)), jnp.float32)
        return q, kc, vc

    @pytest.mark.parametrize("b", [1, 2, 4, 8])
    def test_matches_ref(self, rng, b):
        smax = 96
        q, kc, vc = self._cache(rng, b, smax)
        pos = jnp.asarray(rng.integers(0, smax, size=b), jnp.int32)
        out = decode_attention(q, kc, vc, pos)
        want = ref_decode_attention(q, kc, vc, pos)
        np.testing.assert_allclose(out, want, **TOL)

    def test_position_zero(self, rng):
        """position 0 -> attends only slot 0 -> output == V[0] per head group."""
        q, kc, vc = self._cache(rng, 2, 64)
        pos = jnp.asarray([0, 0], jnp.int32)
        out = decode_attention(q, kc, vc, pos)
        group = HQ // HKV
        want = np.repeat(np.asarray(vc[:, :, 0, :]), group, axis=1)
        np.testing.assert_allclose(out, want, **TOL)

    def test_mask_excludes_stale_slots(self, rng):
        """Garbage beyond positions[b] must not leak into the output."""
        q, kc, vc = self._cache(rng, 2, 64)
        pos = jnp.asarray([10, 20], jnp.int32)
        base = decode_attention(q, kc, vc, pos)
        kc2 = kc.at[:, :, 40:, :].set(1e6)
        vc2 = vc.at[:, :, 40:, :].set(-1e6)
        pert = decode_attention(q, kc2, vc2, pos)
        np.testing.assert_allclose(base, pert, **TOL)

    def test_batch_independence(self, rng):
        """Each request's output depends only on its own cache row."""
        q, kc, vc = self._cache(rng, 4, 64)
        pos = jnp.asarray([5, 10, 15, 20], jnp.int32)
        base = decode_attention(q, kc, vc, pos)
        kc2 = kc.at[2].set(
            jnp.asarray(rng.normal(size=kc.shape[1:]), jnp.float32))
        pert = decode_attention(q, kc2, vc, pos)
        keep = np.asarray([0, 1, 3])
        np.testing.assert_allclose(
            np.asarray(base)[keep], np.asarray(pert)[keep], **TOL)
        assert not np.allclose(base[2], pert[2])

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 8),
        smax=st.sampled_from([32, 64, 160]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property(self, b, smax, seed):
        rng = np.random.default_rng(seed)
        q, kc, vc = self._cache(rng, b, smax)
        pos = jnp.asarray(rng.integers(0, smax, size=b), jnp.int32)
        out = decode_attention(q, kc, vc, pos)
        want = ref_decode_attention(q, kc, vc, pos)
        np.testing.assert_allclose(out, want, **TOL)
